//! Streamed-gradient seam equivalence (ISSUE 2 acceptance):
//!
//! * streamed and collected execution produce bit-identical gradients on
//!   every native preset;
//! * fused-update FPFT and HiFT (m=1 and m>1) land on parameters
//!   bit-identical to the pre-refactor collect-then-update path (encoded
//!   here as the reference loops);
//! * the double-buffered pipeline is bit-identical to the serial sink;
//! * `peak_grad_resident_bytes` under streamed HiFT is one tensor — the
//!   largest in the group — while the collected path holds the whole set.
//!
//! Activation checkpointing + crash-safe resume (ISSUE 3 acceptance):
//!
//! * recompute-on-backward is bit-identical to the cached path on every
//!   preset and all four model variants;
//! * `peak_act_resident_bytes` is monotone (`none ≥ every_k(2) ≥ sqrt`)
//!   and `sqrt` drops it ≥ 2× on the default preset;
//! * a HiFT run checkpointed mid-sweep and resumed is bit-identical to an
//!   uninterrupted run (loss curve, params, final eval);
//! * corrupt checkpoints (bad offset/shape, overlap, duplicates) load as
//!   `Err`, never a panic.

use hift::backend::{
    unit_artifact, ActCkpt, Batch, ExecBackend, GradSink, NativeBackend, PRESET_NAMES,
};
use hift::coordinator::lr::LrSchedule;
use hift::coordinator::scheduler::{HiftScheduler, SchedulerCfg};
use hift::coordinator::strategy::UpdateStrategy;
use hift::data::{build_task, TaskGeom};
use hift::optim::{self, OptimCfg, OptimKind};
use hift::rng::Pcg32;
use hift::strategies::{FineTuneStrategy, Hift, HiftCfg, SubsetTune};
use hift::tensor::{Tensor, TensorSet};

fn backend() -> NativeBackend {
    NativeBackend::preset("tiny", 0).expect("tiny preset")
}

fn geom(be: &dyn ExecBackend) -> TaskGeom {
    let c = &be.manifest().config;
    TaskGeom::new(c.vocab, c.batch, c.seq_len)
}

/// A sink that records `(slot, name, grad)` without applying anything.
#[derive(Default)]
struct Recorder {
    grads: Vec<(usize, String, Tensor)>,
}

impl GradSink for Recorder {
    fn grad(
        &mut self,
        slot: usize,
        name: &str,
        grad: Tensor,
        _params: &mut TensorSet,
    ) -> anyhow::Result<()> {
        self.grads.push((slot, name.to_string(), grad));
        Ok(())
    }

    fn resident_bytes(&self) -> u64 {
        self.grads.iter().map(|(_, _, g)| g.bytes() as u64).sum()
    }
}

fn small_batch(vocab: usize, s: usize, seed: u64) -> Batch {
    let mut rng = Pcg32::seeded(seed);
    let mut b = Batch::new(1, s);
    for t in b.tokens.iter_mut() {
        *t = rng.below(vocab) as i32;
    }
    for t in b.targets.iter_mut() {
        *t = rng.below(vocab) as i32;
    }
    for w in b.weights.iter_mut() {
        *w = 1.0;
    }
    b
}

#[test]
fn streamed_equals_collected_grads_on_all_presets() {
    for preset in PRESET_NAMES {
        let mut be = NativeBackend::preset(preset, 1).unwrap();
        let cfg = be.manifest().config.clone();
        let n_units = be.manifest().n_units;
        let mut params = be.load_params("base").unwrap();
        // A 1×4 batch keeps the larger presets tractable in debug test
        // builds while exercising the full layer stack.
        let batch = small_batch(cfg.vocab, cfg.seq_len.min(4), 7);
        // FPFT's artifact plus every HiFT unit artifact on the small
        // presets; a middle unit and the head unit on the big ones.
        let artifacts: Vec<String> = if matches!(preset, "tiny" | "small") {
            let mut a = vec!["grad_base_full".to_string()];
            a.extend((0..n_units).map(unit_artifact));
            a
        } else {
            vec![unit_artifact(1), unit_artifact(n_units - 1)]
        };
        for art in &artifacts {
            let collected = be.run(art, &mut params, &batch).unwrap();
            let mut rec = Recorder::default();
            let streamed = be.run_streamed(art, &mut params, &batch, &mut rec).unwrap();
            assert_eq!(collected.loss, streamed.loss, "{preset}/{art}: loss");
            assert_eq!(collected.ncorrect, streamed.ncorrect, "{preset}/{art}: ncorrect");
            assert_eq!(rec.grads.len(), collected.grads.len(), "{preset}/{art}: grad count");
            let mut by_slot = rec.grads;
            by_slot.sort_by_key(|(slot, _, _)| *slot);
            for ((slot, name, g), cg) in by_slot.iter().zip(&collected.grads) {
                assert_eq!(g.shape, cg.shape, "{preset}/{art}/{name}");
                assert_eq!(
                    g.data, cg.data,
                    "{preset}/{art}: slot {slot} ({name}) must be bit-identical"
                );
            }
        }
    }
}

/// The pre-refactor FPFT path: collect the full gradient vector, then
/// clip + update tensor-by-tensor in artifact output order.
#[test]
fn fused_fpft_matches_collected_reference() {
    let lr = 3e-3f32;
    let ocfg = OptimCfg::new(OptimKind::AdamW);
    let steps = 6usize;

    let mut be = backend();
    let mut task = build_task("motif4", geom(&be), 11).unwrap();
    let batches: Vec<Batch> = (0..steps).map(|_| task.train_batch()).collect();

    // Streamed + fused (the new SubsetTune path).
    let mut fpft =
        SubsetTune::fpft(be.manifest(), ocfg, LrSchedule::Const { lr }).unwrap();
    let mut p_s = be.load_params("base").unwrap();
    for b in &batches {
        fpft.step(&mut be, &mut p_s, b).unwrap();
    }

    // Collected reference (pre-refactor semantics).
    let n_params = be.manifest().variant("base").unwrap().params.len();
    let mut p_c = be.load_params("base").unwrap();
    let mut opt = optim::build(ocfg, n_params);
    for b in &batches {
        let out = be.run("grad_base_full", &mut p_c, b).unwrap();
        for (idx, mut g) in out.grads.into_iter().enumerate() {
            optim::clip_grad(&mut g, ocfg.grad_clip);
            opt.update(idx, p_c.tensor_mut(idx), &g, lr);
        }
    }

    for ((name, ts), tc) in p_s.names.iter().zip(&p_s.tensors).zip(&p_c.tensors) {
        assert_eq!(ts.data, tc.data, "{name}: streamed FPFT must equal collected path");
    }
}

/// The pre-refactor HiFT path: per step, run every unit artifact of the
/// group collecting all gradients, then clip + update jointly.
fn hift_collected_reference(
    be: &mut NativeBackend,
    m: usize,
    lr: f32,
    ocfg: OptimCfg,
    batches: &[Batch],
) -> TensorSet {
    let manifest = be.manifest().clone();
    let vinfo = manifest.variant("base").unwrap();
    let mut scheduler = HiftScheduler::new(
        SchedulerCfg {
            m,
            strategy: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr },
        },
        manifest.n_units,
    );
    let mut params = be.load_params("base").unwrap();
    let mut opt = optim::build(ocfg, vinfo.params.len());
    for b in batches {
        let plan = scheduler.next();
        let mut grads: Vec<(usize, Tensor)> = Vec::new();
        for &u in &plan.units {
            let out = be.run(&unit_artifact(u), &mut params, b).unwrap();
            for (slot, g) in vinfo.unit_indices(u).into_iter().zip(out.grads) {
                grads.push((slot, g));
            }
        }
        for (idx, mut g) in grads {
            optim::clip_grad(&mut g, ocfg.grad_clip);
            opt.update(idx, params.tensor_mut(idx), &g, plan.lr);
        }
    }
    params
}

fn run_streamed_hift(
    be: &mut NativeBackend,
    m: usize,
    lr: f32,
    ocfg: OptimCfg,
    batches: &[Batch],
    pipeline: bool,
) -> TensorSet {
    let manifest = be.manifest().clone();
    let cfg = HiftCfg {
        m,
        order: UpdateStrategy::Bottom2Up,
        schedule: LrSchedule::Const { lr },
        optim: ocfg,
    };
    let mut hift = Hift::pipelined(cfg, &manifest, pipeline).unwrap();
    let mut params = be.load_params("base").unwrap();
    for b in batches {
        hift.step(&mut *be, &mut params, b).unwrap();
    }
    params
}

#[test]
fn streamed_hift_matches_collected_reference_m1_and_m2() {
    let lr = 3e-3f32;
    let ocfg = OptimCfg::new(OptimKind::AdamW);
    for m in [1usize, 2] {
        let mut be = backend();
        let n_units = be.manifest().n_units;
        let mut task = build_task("motif4", geom(&be), 5).unwrap();
        // Two full sweeps so every group updates twice.
        let k = n_units.div_ceil(m);
        let batches: Vec<Batch> = (0..2 * k).map(|_| task.train_batch()).collect();

        let p_ref = hift_collected_reference(&mut be, m, lr, ocfg, &batches);
        let p_str = run_streamed_hift(&mut be, m, lr, ocfg, &batches, false);
        for ((name, a), b) in p_str.names.iter().zip(&p_str.tensors).zip(&p_ref.tensors) {
            assert_eq!(
                a.data, b.data,
                "m={m} {name}: streamed HiFT must equal the collected path"
            );
        }
    }
}

#[test]
fn pipelined_hift_matches_serial_streamed() {
    let lr = 4e-3f32;
    let ocfg = OptimCfg::new(OptimKind::AdamW);
    let mut be = backend();
    let n_units = be.manifest().n_units;
    let mut task = build_task("markovlm", geom(&be), 9).unwrap();
    let batches: Vec<Batch> = (0..2 * n_units).map(|_| task.train_batch()).collect();

    let p_serial = run_streamed_hift(&mut be, 2, lr, ocfg, &batches, false);
    let p_pipe = run_streamed_hift(&mut be, 2, lr, ocfg, &batches, true);
    for ((name, a), b) in p_pipe.names.iter().zip(&p_pipe.tensors).zip(&p_serial.tensors) {
        assert_eq!(a.data, b.data, "{name}: pipelined updates must be bit-identical");
    }
}

#[test]
fn hift_group_runs_one_execution_per_step() {
    // m>1 used to cost one forward per unit; the grouped streamed run is a
    // single execution per step.
    let mut be = backend();
    let manifest = be.manifest().clone();
    let mut hift = Hift::pipelined(
        HiftCfg {
            m: 2,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr: 1e-3 },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        &manifest,
        false,
    )
    .unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif2", geom(&be), 3).unwrap();
    let steps = 4u64;
    for _ in 0..steps {
        let b = task.train_batch();
        hift.step(&mut be, &mut params, &b).unwrap();
    }
    assert_eq!(be.stats().executions, steps, "one grouped execution per step");
}

#[test]
fn streamed_hift_peak_grad_residency_is_one_tensor() {
    let mut be = backend();
    let manifest = be.manifest().clone();
    let vinfo = manifest.variant("base").unwrap();
    let n_units = manifest.n_units;
    let max_tensor_bytes = vinfo.params.iter().map(|p| p.size * 4).max().unwrap() as u64;
    let group_sum_bytes: u64 = {
        // Largest group (m=2, fixed chunks) by total gradient bytes.
        (0..n_units)
            .step_by(2)
            .map(|start| {
                vinfo
                    .params
                    .iter()
                    .filter(|p| p.unit >= start as i64 && p.unit < start as i64 + 2)
                    .map(|p| (p.size * 4) as u64)
                    .sum()
            })
            .max()
            .unwrap()
    };
    assert!(group_sum_bytes > max_tensor_bytes, "group must span several tensors");

    let mut task = build_task("motif4", geom(&be), 3).unwrap();
    let batches: Vec<Batch> = (0..n_units).map(|_| task.train_batch()).collect();
    let _ = run_streamed_hift(&mut be, 2, 1e-3, OptimCfg::new(OptimKind::AdamW), &batches, false);
    assert_eq!(
        be.stats().peak_grad_resident_bytes,
        max_tensor_bytes,
        "streamed HiFT holds at most the group's largest single tensor"
    );

    // The collected path (pre-refactor semantics) holds the whole group.
    let mut be2 = backend();
    let _ = hift_collected_reference(
        &mut be2,
        2,
        1e-3,
        OptimCfg::new(OptimKind::AdamW),
        &batches,
    );
    assert!(
        be2.stats().peak_grad_resident_bytes >= group_sum_bytes / 2,
        "collected path accumulates whole units ({} < {})",
        be2.stats().peak_grad_resident_bytes,
        group_sum_bytes / 2,
    );
    assert!(
        be2.stats().peak_grad_resident_bytes > be.stats().peak_grad_resident_bytes,
        "collected residency must exceed streamed residency"
    );
}

#[test]
fn recompute_equals_cached_for_all_presets_and_variants() {
    for preset in PRESET_NAMES {
        let mut be = NativeBackend::preset(preset, 3).unwrap();
        let cfg = be.manifest().config.clone();
        let small = matches!(preset, "tiny" | "small");
        // Every variant's gradient artifact; the base unit artifact also
        // exercises recompute under truncated backprop.
        let mut cases: Vec<(&str, String)> = vec![
            ("lora", "grad_lora_adapter".to_string()),
            ("ia3", "grad_ia3_adapter".to_string()),
            ("prefix", "grad_prefix_adapter".to_string()),
            ("base", unit_artifact(1)),
        ];
        if small {
            cases.push(("base", "grad_base_full".to_string()));
        }
        let policies: &[ActCkpt] = if small {
            &[ActCkpt::EveryK(1), ActCkpt::EveryK(2), ActCkpt::Sqrt]
        } else {
            &[ActCkpt::Sqrt]
        };
        let batch = small_batch(cfg.vocab, cfg.seq_len.min(4), 17);
        for (variant, art) in &cases {
            let mut params = be.load_params(variant).unwrap();
            be.set_act_ckpt(ActCkpt::None).unwrap();
            let reference = be.run(art, &mut params, &batch).unwrap();
            for &policy in policies {
                be.set_act_ckpt(policy).unwrap();
                let got = be.run(art, &mut params, &batch).unwrap();
                assert_eq!(reference.loss, got.loss, "{preset}/{art}/{policy:?}: loss");
                assert_eq!(reference.grads.len(), got.grads.len(), "{preset}/{art}/{policy:?}");
                for (i, (a, g)) in reference.grads.iter().zip(&got.grads).enumerate() {
                    assert_eq!(
                        a.data, g.data,
                        "{preset}/{art}/{policy:?}: grad slot {i} must be bit-identical"
                    );
                }
            }
        }
    }
}

#[test]
fn act_residency_is_monotone_and_sqrt_halves_the_default_preset() {
    for preset in ["tiny", "small", "base"] {
        let mut be = NativeBackend::preset(preset, 5).unwrap();
        let cfg = be.manifest().config.clone();
        let mut params = be.load_params("base").unwrap();
        let batch = small_batch(cfg.vocab, cfg.seq_len.min(8), 23);
        let mut peaks = Vec::new();
        for policy in [ActCkpt::None, ActCkpt::EveryK(2), ActCkpt::Sqrt] {
            be.set_act_ckpt(policy).unwrap();
            be.reset_run_peaks();
            let recompute_before = be.stats().recompute_layers;
            let _ = be.run("grad_base_full", &mut params, &batch).unwrap();
            peaks.push(be.stats().peak_act_resident_bytes);
            let recomputed = be.stats().recompute_layers - recompute_before;
            if policy == ActCkpt::None {
                assert_eq!(recomputed, 0, "{preset}: cached path must not recompute");
            } else {
                assert!(recomputed > 0, "{preset}/{policy:?}: recompute path must be exercised");
            }
        }
        assert!(
            peaks[0] >= peaks[1] && peaks[1] >= peaks[2],
            "{preset}: peak act residency must be monotone none ≥ every_k(2) ≥ sqrt: {peaks:?}"
        );
        if preset == "tiny" {
            // Acceptance: sqrt drops the peak ≥ 2× on the default preset.
            assert!(
                peaks[2] * 2 <= peaks[0],
                "tiny: sqrt peak {} must be ≤ half of none peak {}",
                peaks[2],
                peaks[0]
            );
        }
    }
}

#[test]
fn hift_training_under_act_ckpt_is_bit_identical() {
    let lr = 3e-3f32;
    let ocfg = OptimCfg::new(OptimKind::AdamW);
    let mut be_ref = backend();
    let n_units = be_ref.manifest().n_units;
    let mut task = build_task("motif4", geom(&be_ref), 5).unwrap();
    let batches: Vec<Batch> = (0..2 * n_units).map(|_| task.train_batch()).collect();

    let p_ref = run_streamed_hift(&mut be_ref, 2, lr, ocfg, &batches, false);
    let mut be_ck = backend();
    be_ck.set_act_ckpt(ActCkpt::Sqrt).unwrap();
    let p_ck = run_streamed_hift(&mut be_ck, 2, lr, ocfg, &batches, false);
    for ((name, a), b) in p_ck.names.iter().zip(&p_ck.tensors).zip(&p_ref.tensors) {
        assert_eq!(a.data, b.data, "{name}: act-ckpt training must be bit-identical");
    }
    assert!(be_ck.stats().recompute_layers > 0, "ckpt run must have recomputed layers");
    assert!(
        be_ck.stats().peak_act_resident_bytes < be_ref.stats().peak_act_resident_bytes,
        "ckpt run must retain fewer activations ({} vs {})",
        be_ck.stats().peak_act_resident_bytes,
        be_ref.stats().peak_act_resident_bytes
    );
}

#[test]
fn mid_sweep_kill_and_resume_is_bit_identical() {
    use hift::coordinator::trainer::{self, CkptOpts, TrainCfg};
    use hift::tensor::checkpoint;

    let dir = std::env::temp_dir().join(format!("hift_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let steps = 10u64;
    let kill_at = 6u64; // tiny: 4 units, m=1 ⇒ k=4, so step 6 is mid-sweep
    let mk_cfg = || HiftCfg {
        m: 1,
        order: UpdateStrategy::Bottom2Up,
        schedule: LrSchedule::Linear { lr: 4e-3, warmup: 0, total: 8 },
        optim: OptimCfg::new(OptimKind::AdamW),
    };
    let train_cfg = TrainCfg { steps, eval_every: 0, log_every: 0 };

    // Uninterrupted reference run.
    let mut be = backend();
    let manifest = be.manifest().clone();
    let mut hift = Hift::pipelined(mk_cfg(), &manifest, false).unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 21).unwrap();
    let full = trainer::train(&mut be, &mut hift, &mut params, task.as_mut(), train_cfg).unwrap();

    // Interrupted run: train to kill_at with periodic checkpointing…
    let mut be1 = backend();
    let mut h1 = Hift::pipelined(mk_cfg(), &manifest, false).unwrap();
    assert!(kill_at % h1.k() as u64 != 0, "kill point must land mid-sweep for this test");
    let mut p1 = be1.load_params("base").unwrap();
    let mut t1 = build_task("motif4", geom(&be1), 21).unwrap();
    let part = trainer::train_ckpt(
        &mut be1,
        &mut h1,
        &mut p1,
        t1.as_mut(),
        TrainCfg { steps: kill_at, eval_every: 0, log_every: 0 },
        &CkptOpts { save_dir: Some(dir.clone()), save_every: 3, ..Default::default() },
    )
    .unwrap();
    assert_eq!(part.losses.values[..], full.losses.values[..kill_at as usize]);

    // …then "crash": discard everything and resume purely from disk.
    let ck = checkpoint::load(&dir).unwrap();
    assert_eq!(ck.meta.step, kill_at);
    assert_eq!(ck.meta.sweep, Some(kill_at / h1.k() as u64));
    assert!(!ck.opt_state.is_empty(), "AdamW moments must be checkpointed");
    let mut be2 = backend();
    let mut h2 = Hift::pipelined(mk_cfg(), &manifest, false).unwrap();
    let mut p2 = ck.params;
    h2.import_opt_state(&ck.opt_state, &p2).unwrap();
    let mut t2 = build_task("motif4", geom(&be2), 21).unwrap();
    let resumed = trainer::train_ckpt(
        &mut be2,
        &mut h2,
        &mut p2,
        t2.as_mut(),
        train_cfg,
        &CkptOpts {
            start_step: ck.meta.step,
            expect_sweep: ck.meta.sweep,
            ..Default::default()
        },
    )
    .unwrap();

    // The resumed segment must be the exact tail of the uninterrupted run…
    assert_eq!(resumed.losses.values[..], full.losses.values[kill_at as usize..]);
    // …and land on bit-identical parameters and final eval.
    for ((name, a), b) in p2.names.iter().zip(&p2.tensors).zip(&params.tensors) {
        assert_eq!(a.data, b.data, "{name}: resumed params must equal uninterrupted run");
    }
    assert_eq!(resumed.final_eval, full.final_eval);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_with_changed_config_is_rejected() {
    use hift::coordinator::trainer::{self, CkptOpts, TrainCfg};
    // A checkpoint claiming a sweep index the replayed schedule cannot
    // reach must be refused (m/order changed between save and resume).
    let mut be = backend();
    let manifest = be.manifest().clone();
    let mut hift = Hift::pipelined(
        HiftCfg {
            m: 2, // k=2 ⇒ step 6 lands on sweep 3, not the recorded 1
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr: 1e-3 },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        &manifest,
        false,
    )
    .unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 9).unwrap();
    let err = trainer::train_ckpt(
        &mut be,
        &mut hift,
        &mut params,
        task.as_mut(),
        TrainCfg { steps: 10, eval_every: 0, log_every: 0 },
        &CkptOpts { start_step: 6, expect_sweep: Some(1), ..Default::default() },
    )
    .unwrap_err();
    assert!(err.to_string().contains("sweep"), "{err}");
}

#[test]
fn empty_eval_set_is_a_clear_error_not_nan() {
    use hift::coordinator::trainer;
    let mut be = backend();
    let mut params = be.load_params("base").unwrap();
    let err = trainer::evaluate(&mut be, "fwd_base", &mut params, &[]).unwrap_err();
    assert!(err.to_string().contains("no eval batches"), "{err}");
}

#[test]
fn corrupt_checkpoints_error_instead_of_panicking() {
    use hift::tensor::checkpoint;

    let dir = std::env::temp_dir().join(format!("hift_ckpt_fuzz_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // params.bin: 10 f32 = 40 bytes of zeros.
    std::fs::write(dir.join("params.bin"), vec![0u8; 40]).unwrap();
    let write_meta = |tensors: &str| {
        let json = format!(
            "{{\"schema\": 1, \"step\": 0, \"strategy\": \"s\", \"task\": \"t\", \
             \"total_bytes\": 40, \"tensors\": [{tensors}]}}"
        );
        std::fs::write(dir.join("ckpt.json"), json).unwrap();
    };

    // Sanity: a well-formed schema-1 inventory loads, and its missing
    // sweep field reads back as None (so resume skips the sweep
    // cross-check instead of falsely rejecting old checkpoints).
    write_meta("{\"name\": \"a\", \"shape\": [10], \"offset\": 0}");
    let ck = checkpoint::load(&dir).unwrap();
    assert_eq!(ck.meta.sweep, None, "schema-1 checkpoints have no sweep index");

    let cases: &[(&str, &str)] = &[
        ("offset past the end", "{\"name\": \"a\", \"shape\": [10], \"offset\": 8}"),
        ("negative offset", "{\"name\": \"a\", \"shape\": [4], \"offset\": -4}"),
        (
            "shape product overflow",
            "{\"name\": \"a\", \"shape\": [4294967296, 4294967296], \"offset\": 0}",
        ),
        ("fractional shape", "{\"name\": \"a\", \"shape\": [2.5], \"offset\": 0}"),
        ("non-numeric shape", "{\"name\": \"a\", \"shape\": [\"x\"], \"offset\": 0}"),
        (
            "overlapping regions",
            "{\"name\": \"a\", \"shape\": [6], \"offset\": 0}, \
             {\"name\": \"b\", \"shape\": [6], \"offset\": 16}",
        ),
        (
            "duplicate names",
            "{\"name\": \"a\", \"shape\": [2], \"offset\": 0}, \
             {\"name\": \"a\", \"shape\": [2], \"offset\": 8}",
        ),
    ];
    for (what, tensors) in cases {
        write_meta(tensors);
        match std::panic::catch_unwind(|| checkpoint::load(&dir)) {
            Ok(res) => assert!(res.is_err(), "{what}: corrupt checkpoint must load as Err"),
            Err(_) => panic!("{what}: load panicked on corrupt metadata"),
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_record_surfaces_backend_stats_and_grad_peak() {
    use hift::coordinator::trainer::{self, TrainCfg};
    let mut be = backend();
    let manifest = be.manifest().clone();
    let mut hift = Hift::pipelined(
        HiftCfg {
            m: 1,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr: 2e-3 },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        &manifest,
        false,
    )
    .unwrap();
    let mut params = be.load_params("base").unwrap();
    let mut task = build_task("motif4", geom(&be), 7).unwrap();
    let rec = trainer::train(
        &mut be,
        &mut hift,
        &mut params,
        task.as_mut(),
        TrainCfg { steps: 4, eval_every: 0, log_every: 0 },
    )
    .unwrap();
    assert!(rec.backend.executions > 4, "train steps + eval forwards");
    assert!(rec.backend.cache_hits + rec.backend.cache_misses > 0);
    assert!(rec.backend.h2d_bytes > 0 && rec.backend.d2h_bytes > 0);
    assert!(rec.backend.peak_grad_resident_bytes > 0);
    let ledger_peak = rec.peak_grad_resident_bytes.expect("hift has a ledger");
    assert_eq!(
        ledger_peak, rec.backend.peak_grad_resident_bytes,
        "fused sink holds exactly what the backend streams"
    );
    let json = hift::ser::emit_pretty(&rec.to_json());
    for key in ["cache_hits", "cache_misses", "peak_grad_resident_bytes", "executions"] {
        assert!(json.contains(key), "RunRecord JSON must surface {key}");
    }
}
