//! API **stub** of the XLA/PJRT binding `hift`'s `pjrt` feature compiles
//! against.
//!
//! The offline build environment has no real PJRT binding, so this crate
//! provides the exact type/method surface `hift::runtime` uses — enough for
//! `cargo build --features pjrt` to type-check — while every constructor
//! returns a clear runtime error.  To actually execute AOT artifacts,
//! replace `rust/vendor/xla` with a real binding exposing the same API
//! (modeled on xla-rs: `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `compile` → `execute_b`).

use std::path::Path;

/// Stub error: every entry point returns it.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} is unavailable — this build links the vendored API stub; \
         replace rust/vendor/xla with a real PJRT binding"
    )))
}

/// Element types marshallable to device buffers.
pub trait ElementType: Copy {}
impl ElementType for f32 {}
impl ElementType for i32 {}
impl ElementType for i64 {}
impl ElementType for u8 {}

/// Device-resident buffer (never constructible through the stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

/// Compiled executable (never constructible through the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b<T: BufferArg>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Things acceptable as execute arguments.
pub trait BufferArg {}
impl BufferArg for &PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host-side literal value.
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module (text interchange).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

/// Device handle (only named in option types).
#[derive(Debug)]
pub struct PjRtDevice(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}
