//! `cargo bench --bench bench_parallel` — the data-parallel scaling
//! exhibit: measured step throughput vs worker count N, bit-identity of
//! the N-worker runs against the serial walk (losses, eval, kernel flop
//! totals, peak grad residency), and the analytic replica-overhead panel
//! (see hift::bench::exhibits).
fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut b = hift::bench::Bench::from_env()?;
    hift::bench::exhibits::parallel(&mut b)?;
    eprintln!("[bench_parallel] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
