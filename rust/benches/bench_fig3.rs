//! `cargo bench --bench bench_fig3` — regenerates the paper's fig3 exhibit
//! (see DESIGN.md §4 and hift::bench::exhibits).
fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut b = hift::bench::Bench::from_env()?;
    hift::bench::exhibits::fig3(&mut b)?;
    eprintln!("[bench_fig3] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
