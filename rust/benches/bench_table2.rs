//! `cargo bench --bench bench_table2` — regenerates the paper's table2 exhibit
//! (see DESIGN.md §4 and hift::bench::exhibits).
fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut b = hift::bench::Bench::from_env()?;
    hift::bench::exhibits::table2(&mut b)?;
    eprintln!("[bench_table2] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
