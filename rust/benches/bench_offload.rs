//! `cargo bench --bench bench_offload` — the host-paging tier exhibit:
//! synchronous vs double-buffered prefetched paging vs fully-resident HiFT
//! stepping across group sizes m (see hift::bench::exhibits::offload).
fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut b = hift::bench::Bench::from_env()?;
    hift::bench::exhibits::offload(&mut b)?;
    eprintln!("[bench_offload] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
