//! `cargo bench --bench bench_kernels` — the kernel-layer exhibit: naive
//! vs cache-blocked vs blocked+SIMD GEMM throughput (bit-identical f32
//! results), end-to-end per-kind runs, and the fused streaming-softmax
//! attention's measured peak-activation saving (see hift::bench::exhibits).
fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut b = hift::bench::Bench::from_env()?;
    hift::bench::exhibits::kernels(&mut b)?;
    eprintln!("[bench_kernels] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
