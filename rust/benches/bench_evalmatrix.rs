//! `cargo bench --bench bench_evalmatrix` — the strategy × task-family
//! eval matrix over the forge templates: every strategy trains on every
//! `MATRIX_FAMILIES` stream and the scoreboard JSON (`runs/evalmatrix.json`)
//! records per-cell loss/accuracy, residency peaks, kernel throughput, and
//! stream diversity/dedup stats (see hift::bench::exhibits::evalmatrix).
fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut b = hift::bench::Bench::from_env()?;
    hift::bench::exhibits::evalmatrix(&mut b)?;
    eprintln!("[bench_evalmatrix] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
