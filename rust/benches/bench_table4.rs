//! `cargo bench --bench bench_table4` — regenerates the paper's table4 exhibit
//! (see DESIGN.md §4 and hift::bench::exhibits).
fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut b = hift::bench::Bench::from_env()?;
    hift::bench::exhibits::table4(&mut b)?;
    eprintln!("[bench_table4] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
