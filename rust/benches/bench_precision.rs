//! `cargo bench --bench bench_precision` — the mixed-precision compute
//! exhibit: f32 vs bf16 vs f16 throughput, peak activation bytes and loss
//! drift (see hift::bench::exhibits).
fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut b = hift::bench::Bench::from_env()?;
    hift::bench::exhibits::precision(&mut b)?;
    eprintln!("[bench_precision] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
