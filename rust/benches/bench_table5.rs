//! `cargo bench --bench bench_table5` — regenerates the paper's table5 exhibit
//! (see DESIGN.md §4 and hift::bench::exhibits).
fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut b = hift::bench::Bench::from_env()?;
    hift::bench::exhibits::table5(&mut b)?;
    eprintln!("[bench_table5] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
