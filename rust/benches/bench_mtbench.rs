//! `cargo bench --bench bench_mtbench` — regenerates the paper's mtbench exhibit
//! (see DESIGN.md §4 and hift::bench::exhibits).
fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut b = hift::bench::Bench::from_env()?;
    hift::bench::exhibits::mtbench(&mut b)?;
    eprintln!("[bench_mtbench] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
