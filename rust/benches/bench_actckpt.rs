//! `cargo bench --bench bench_actckpt` — the activation-checkpointing
//! memory-vs-recompute-time tradeoff exhibit (see hift::bench::exhibits).
fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let mut b = hift::bench::Bench::from_env()?;
    hift::bench::exhibits::act_ckpt(&mut b)?;
    eprintln!("[bench_actckpt] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
