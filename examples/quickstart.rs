//! Quickstart: HiFT-train a tiny transformer for a few sweeps and watch the
//! loss fall, then compare the per-step trainable footprint against FPFT.
//!
//! Runs fully offline on the native CPU backend:
//!
//! ```bash
//! cargo run --release --example quickstart
//! # other geometries / engines:
//! HIFT_PRESET=small cargo run --release --example quickstart
//! HIFT_ARTIFACTS=artifacts/tiny cargo run --release --features pjrt --example quickstart
//! ```

use hift::backend::ExecBackend;
use hift::coordinator::lr::LrSchedule;
use hift::coordinator::strategy::UpdateStrategy;
use hift::coordinator::trainer::{self, TrainCfg};
use hift::data::{build_task, TaskGeom};
use hift::optim::{OptimCfg, OptimKind};
use hift::strategies::{FineTuneStrategy, Hift, HiftCfg};

fn main() -> anyhow::Result<()> {
    let mut rt = hift::backend::from_env()?;
    let cfg = rt.manifest().config.clone();
    println!(
        "loaded {} (vocab={} d={} L={}) on {}",
        rt.manifest().preset, cfg.vocab, cfg.d_model, cfg.n_layers, rt.platform()
    );

    // The paper's recipe: m=1, bottom2up, AdamW, delayed LR.
    let mut hift = Hift::new(
        HiftCfg {
            m: 1,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Const { lr: 4e-3 },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        rt.manifest(),
    )?;
    let mut params = rt.load_params("base")?;
    let total = params.total_params();
    let mut task = build_task("motif4", TaskGeom::new(cfg.vocab, cfg.batch, cfg.seq_len), 42).unwrap();

    let k = hift.k() as u64;
    let steps = 8 * k; // eight full sweeps
    let rec = trainer::train(rt.as_mut(), &mut hift, &mut params, task.as_mut(), TrainCfg {
        steps,
        eval_every: 2 * k,
        log_every: k,
    })?;

    println!("\nloss: {:.3} -> {:.3}", rec.losses.values[0], rec.losses.tail_mean(4));
    println!("eval accuracy: {:.1}%", rec.final_eval.acc * 100.0);
    println!(
        "peak trainable params/step: {} / {} total ({:.1}%)",
        rec.peak_trainable_params,
        total,
        rec.peak_trainable_params as f64 / total as f64 * 100.0
    );
    if let Some((h2d, d2h, inflight, peak)) = rec.paging {
        println!(
            "optimizer-state paging: {:.2} MiB h2d, {:.2} MiB d2h, peak inflight {:.2} MiB, peak device {:.2} MiB",
            h2d as f64 / 1048576.0,
            d2h as f64 / 1048576.0,
            inflight as f64 / 1048576.0,
            peak as f64 / 1048576.0
        );
    }
    assert!(rec.losses.tail_mean(4) < rec.losses.values[0], "loss should fall");
    println!("\nquickstart OK");
    Ok(())
}
