//! Figure 4 in miniature: train the same model on the same task under the
//! three update orders (B2U / T2D / RAN) and several group sizes m, and
//! show that final quality is insensitive to both — the paper's §4.6/§4.7
//! finding that motivates future block-parallel fine-tuning.
//!
//! ```bash
//! cargo run --release --example strategy_ablation
//! ```

use hift::backend::ExecBackend;
use hift::coordinator::lr::LrSchedule;
use hift::coordinator::strategy::UpdateStrategy;
use hift::coordinator::trainer::{self, TrainCfg};
use hift::data::{build_task, TaskGeom};
use hift::optim::{OptimCfg, OptimKind};
use hift::strategies::{FineTuneStrategy, Hift, HiftCfg};

fn run(
    rt: &mut dyn ExecBackend,
    order: UpdateStrategy,
    m: usize,
    steps: u64,
) -> anyhow::Result<(f64, f64)> {
    let cfg = rt.manifest().config.clone();
    let mut hift = Hift::new(
        HiftCfg {
            m,
            order,
            schedule: LrSchedule::Const { lr: 4e-3 },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        rt.manifest(),
    )?;
    let mut params = rt.load_params("base")?;
    let mut task = build_task("motif4", TaskGeom::new(cfg.vocab, cfg.batch, cfg.seq_len), 77).unwrap();
    let rec = trainer::train(rt, &mut hift, &mut params, task.as_mut(),
        TrainCfg { steps, eval_every: 0, log_every: 0 })?;
    Ok((rec.final_eval.acc, rec.losses.tail_mean(8)))
}

fn main() -> anyhow::Result<()> {
    let mut rt = hift::backend::from_env()?;
    let steps: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(120);

    println!("-- update-order ablation (m=1, {steps} steps) --");
    let mut accs = Vec::new();
    for (label, order) in [
        ("bottom2up", UpdateStrategy::Bottom2Up),
        ("top2down", UpdateStrategy::Top2Down),
        ("random", UpdateStrategy::Random { seed: 7 }),
    ] {
        let (acc, loss) = run(rt.as_mut(), order, 1, steps)?;
        println!("  {label:<10} acc={:.1}%  tail-loss={loss:.4}", acc * 100.0);
        accs.push(acc);
    }
    let spread = accs.iter().cloned().fold(f64::MIN, f64::max)
        - accs.iter().cloned().fold(f64::MAX, f64::min);
    println!("  order spread: {:.1} points (paper: ~no effect)", spread * 100.0);

    println!("\n-- group-size ablation (bottom2up, {steps} steps) --");
    let n_units = rt.manifest().n_units;
    for m in [1usize, 2, n_units] {
        let (acc, loss) = run(rt.as_mut(), UpdateStrategy::Bottom2Up, m, steps)?;
        let k = n_units.div_ceil(m);
        println!("  m={m:<2} (k={k:<2}) acc={:.1}%  tail-loss={loss:.4}", acc * 100.0);
    }
    Ok(())
}
