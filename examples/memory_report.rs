//! Regenerate the paper's memory exhibits from the analytic model:
//! Tables 8–12 grid, Figure 6 composition/pies, and the Appendix-B
//! closed form — without touching a GPU.
//!
//! ```bash
//! cargo run --release --example memory_report
//! cargo run --release --example memory_report -- --model llama-7b --batch 1
//! ```

use hift::cli::Args;
use hift::memmodel::{account, appendix_b_ratio, by_name, zoo, Dtype, Method, Workload, GIB, MIB};
use hift::optim::OptimKind;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let w = Workload {
        batch: args.get_num("batch").unwrap_or(8.0) as usize,
        seq: args.get_num("seq").unwrap_or(512.0) as usize,
    };
    let models: Vec<String> = match args.get("model") {
        Some(m) => vec![m.to_string()],
        None => vec!["roberta-base".into(), "roberta-large".into(), "gpt2-large".into(),
                     "gpt-neo-2.7b".into(), "llama-7b".into()],
    };

    for name in &models {
        let a = by_name(name).expect("unknown model");
        println!("\n### {name} — b={} s={} ###", w.batch, w.seq);
        println!("{:<10} {:<8} {:<5} {:>9} {:>11} {:>10} {:>10} {:>9} {:>9} {:>9}",
                 "optim", "dtype", "ftype", "#Train(M)", "#Para(MiB)", "#Gra(MiB)",
                 "#Sta(MiB)", "PGS(GiB)", "Res(GiB)", "Tot(GiB)");
        for opt in OptimKind::ALL {
            for (dt, meth) in [
                (Dtype::Fp32, Method::Fpft),
                (Dtype::Fp32, Method::Hift { m: 1 }),
                (Dtype::Mixed, Method::Fpft),
                (Dtype::Mixed, Method::Hift { m: 1 }),
                (Dtype::MixedHi, Method::Hift { m: 1 }),
            ] {
                let r = account(&a, opt, dt, meth, w);
                println!("{:<10} {:<8} {:<5} {:>9.2} {:>11.2} {:>10.2} {:>10.2} {:>9.2} {:>9.2} {:>9.2}",
                         opt.name(), dt.name(),
                         if matches!(meth, Method::Fpft) { "FPFT" } else { "HiFT" },
                         r.trainable as f64 / 1e6, r.para / MIB, r.gra / MIB, r.sta / MIB,
                         r.pgs / GIB, r.residual / GIB, r.total / GIB);
            }
        }
    }

    // Figure 6(e): peak-trainable fraction curve.
    println!("\n### Figure 6(e): peak trainable fraction (m=1) ###");
    for a in zoo() {
        println!("  {:<14} {:>9.1}M total  {:>7.2}M peak  {:>6.2}%",
                 a.name, a.total_params() as f64 / 1e6, a.peak_group_params(1) as f64 / 1e6,
                 a.peak_group_params(1) as f64 / a.total_params() as f64 * 100.0);
    }

    // Headline: 7B on 24G.
    let llama = by_name("llama-7b").unwrap();
    let r = account(&llama, OptimKind::AdamW, Dtype::MixedHi, Method::Hift { m: 1 },
                    Workload { batch: 1, seq: 512 });
    println!("\nheadline: LLaMA-7B, HiFT + adapted mixed precision, batch 1: {:.2} GiB (fits 24G: {})",
             r.total / GIB, r.total / GIB < 24.0);

    println!("\nAppendix B — ζ_hift/ζ_fpft = (k+3)/4k:");
    for k in [2usize, 4, 8, 14, 26, 34] {
        println!("  k={k:<3} ratio={:.3} (saves {:.1}%)", appendix_b_ratio(k),
                 (1.0 - appendix_b_ratio(k)) * 100.0);
    }
    Ok(())
}
