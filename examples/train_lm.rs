//! End-to-end driver (DESIGN.md §5): train a language model of real size
//! for a few hundred steps on the synthetic Markov corpus, logging the loss
//! curve, throughput, and the paging ledger.  Runs on the native CPU
//! backend by default (preset `e2e`, ~27M params); with `--features pjrt`
//! plus `--artifacts DIR` it drives the Pallas-kernel HLO artifacts through
//! PJRT instead.  Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --example train_lm -- --steps 300
//! # smaller/bigger native geometries:
//! cargo run --release --example train_lm -- --preset base --steps 200
//! # the PJRT path (make artifacts-e2e first):
//! HIFT_ARTIFACTS=artifacts/e2e cargo run --release --features pjrt --example train_lm
//! ```

use hift::backend::ExecBackend;
use hift::cli::Args;
use hift::coordinator::lr::LrSchedule;
use hift::coordinator::strategy::UpdateStrategy;
use hift::coordinator::trainer::{self, TrainCfg};
use hift::data::{build_task, TaskGeom};
use hift::optim::{OptimCfg, OptimKind};
use hift::ser::emit_pretty;
use hift::strategies::{FineTuneStrategy, Hift, HiftCfg};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    let artifacts = std::env::var("HIFT_ARTIFACTS")
        .ok()
        .filter(|s| !s.is_empty())
        .or_else(|| args.get("artifacts").map(str::to_string));
    let preset = args.get("preset").unwrap_or("e2e");
    let steps: u64 = args.get_num("steps").unwrap_or(300.0) as u64;

    let mut rt = hift::backend::build_backend(artifacts.as_deref(), Some(preset), 0)?;
    let cfg = rt.manifest().config.clone();
    let mut hift = Hift::new(
        HiftCfg {
            m: args.get_num("m").unwrap_or(1.0) as usize,
            order: UpdateStrategy::Bottom2Up,
            schedule: LrSchedule::Cosine {
                lr: args.get_num("lr").unwrap_or(3e-3) as f32,
                warmup: 2,
                total: (steps as usize / (cfg.n_layers + 2)).max(4),
                min_lr: 1e-5,
            },
            optim: OptimCfg::new(OptimKind::AdamW),
        },
        rt.manifest(),
    )?;
    let mut params = rt.load_params("base")?;
    println!(
        "e2e: {} params={:.2}M units={} k={} steps={steps} platform={}",
        rt.manifest().preset,
        params.total_params() as f64 / 1e6,
        rt.manifest().n_units,
        hift.k(),
        rt.platform()
    );

    let mut task =
        build_task("markovlm4", TaskGeom::new(cfg.vocab, cfg.batch, cfg.seq_len), 1234).unwrap();
    let k = hift.k() as u64;
    let rec = trainer::train(rt.as_mut(), &mut hift, &mut params, task.as_mut(), TrainCfg {
        steps,
        eval_every: (4 * k).min(steps),
        log_every: k,
    })?;

    let st = rt.stats().clone();
    println!(
        "backend: {} executes ({:.1}s), {} compiles ({:.1}s), h2d {:.1} MiB, d2h {:.1} MiB, param-cache {}/{} hits",
        st.executions, st.exec_secs, st.compiles, st.compile_secs,
        st.h2d_bytes as f64 / 1048576.0, st.d2h_bytes as f64 / 1048576.0,
        st.cache_hits, st.cache_hits + st.cache_misses
    );
    println!("\n--- loss curve (downsampled) ---");
    for (i, v) in rec.losses.downsample(24) {
        println!("  step {i:>5}  loss {v:8.4}  {}", "#".repeat((v * 8.0).min(70.0) as usize));
    }
    println!("\nfinal train loss (tail): {:.4}", rec.losses.tail_mean(k as usize));
    println!("eval: acc={:.2}% loss={:.4}", rec.final_eval.acc * 100.0, rec.final_eval.loss);
    println!("throughput: {:.2} steps/s ({:.0}% inside XLA exec)",
             rec.steps_per_sec, rec.exec_secs / rec.wall_secs * 100.0);
    println!("peak trainable: {:.2}M / {:.2}M ({:.2}%)",
             rec.peak_trainable_params as f64 / 1e6,
             params.total_params() as f64 / 1e6,
             rec.peak_trainable_params as f64 / params.total_params() as f64 * 100.0);

    std::fs::create_dir_all("runs")?;
    std::fs::write("runs/e2e.json", emit_pretty(&rec.to_json()))?;
    println!("wrote runs/e2e.json");
    assert!(rec.losses.tail_mean(k as usize) < rec.losses.values[0], "loss must fall");
    Ok(())
}
