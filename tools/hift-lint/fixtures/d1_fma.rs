// Fixture: D1 (fma). Linted as if at rust/src/backend/kernels/fixture.rs.
// The mul_add on line 7 must be the only finding.

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc = a[i].mul_add(b[i], acc);
    }
    acc
}
