// Fixture: D2 (hash-iteration). Linted as if at rust/src/backend/fixture.rs.
// The for-loop on line 12 must be the only finding: the tagged iteration on
// line 20 is suppressed, and the range loop on line 25 is not hash iteration.

use std::collections::HashMap;

pub fn order_sensitive(slots: &HashMap<String, u64>) -> Vec<u64> {
    let mut out = Vec::new();
    let mut index = HashMap::new();
    index.insert(0u32, 0u64);
    let _ = index.get(&0);
    for (_name, slot) in slots {
        out.push(*slot);
    }
    out
}

pub fn order_insensitive(slots: &HashMap<String, u64>) -> u64 {
    // hift-lint: allow(hash-iteration): commutative sum, order-insensitive
    slots.values().sum::<u64>()
}

pub fn ranged(slots: &HashMap<String, u64>) -> usize {
    let mut n = 0;
    for _ in 0..slots.len() {
        n += 1;
    }
    n
}
