// Fixture: D4 (float-reduction). Linted as if at rust/src/optim/fixture.rs.
// The .sum::<f32>() on line 6 and the .fold() on line 10 must both fire;
// try_fold (line 14) and .sum::<u64>() (line 18) must not.

pub fn naive_sum(v: &[f32]) -> f32 {
    v.iter().sum::<f32>()
}

pub fn naive_fold(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |a, b| a + b)
}

pub fn checked(v: &[f32]) -> Option<f32> {
    v.iter().try_fold(0.0f32, |a, b| Some(a + b))
}

pub fn integral(v: &[u64]) -> u64 {
    v.iter().sum::<u64>()
}
