// Fixture: D3 (timing-taint). Linted as if at rust/src/backend/fixture.rs.
// The assignment on line 16 must be the only finding: `tick` (line 8) is a
// sanctioned sink that terminates taint, so line 10 stays clean.

use std::time::Instant;

pub fn mixes_into_numerics(weights: &mut [f32]) {
    let tick_secs = Instant::now().elapsed().as_secs_f64();
    let mut throughput = 0.0f64;
    throughput = throughput + tick_secs;
    let _ = throughput;

    let raw = Instant::now().elapsed().as_secs_f64();
    let jitter = raw * 1e-9;
    let mut scale = 1.0f64;
    scale = scale + jitter;
    weights[0] *= scale as f32;
}
