// Fixture: D3 (timing-taint). Linted as if at rust/src/backend/fixture.rs.
// Findings: line 16 (the `jitter` chain) and line 25 (taint carried across
// a `move ||` closure edge); marker-named bindings terminate taint.

use std::time::Instant;

pub fn mixes_into_numerics(weights: &mut [f32]) {
    let tick_secs = Instant::now().elapsed().as_secs_f64();
    let mut throughput = 0.0f64;
    throughput = throughput + tick_secs;
    let _ = throughput;

    let raw = Instant::now().elapsed().as_secs_f64();
    let jitter = raw * 1e-9;
    let mut scale = 1.0f64;
    scale = scale + jitter;
    weights[0] *= scale as f32;
}

pub fn closure_carries_taint(weights: &mut [f32]) {
    // Taint must survive the move-closure edge: the braced body reads the
    // clock, so `probe` (and then `v`) is clock-derived.
    let probe = move || { Instant::now().elapsed().as_secs_f64() };
    let v = probe();
    weights[1] = v as f32;

    // Marker-named closure bindings stay sanctioned sinks.
    let bench_probe = move || { Instant::now().elapsed().as_secs_f64() };
    let _ = bench_probe();
}
