// Fixture: C1 (budget-lease). Linted as if at rust/src/optim/fixture.rs.
// The spawn on line 6 must be the only finding: the site on line 11 leases
// a worker slot from the ThreadBudget in the same function.

pub fn unleased() -> std::thread::JoinHandle<()> {
    std::thread::spawn(|| {})
}

pub fn leased() -> std::thread::JoinHandle<()> {
    let _slot = par::register_worker();
    std::thread::spawn(|| {})
}
