// Fixture: E1 (ratchet counting). Library-path count must be exactly 3:
// the unwrap on line 7, the expect on line 11, and the panic! on line 15.
// The unwrap inside #[cfg(test)] (line 23) and anything inside comments or
// string literals must not count.

pub fn first(x: Option<u32>) -> u32 {
    x.unwrap()
}

pub fn second(x: Option<u32>) -> u32 {
    x.expect("present")
}

pub fn third() {
    panic!("boom");
    // the literal "panic!(...)" in a string: "panic!(no)"
}

#[cfg(test)]
mod tests {
    #[test]
    fn exempt() {
        Some(1u32).unwrap();
    }
}
