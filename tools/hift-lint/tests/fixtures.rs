//! Fixture tests: each lint fires on its intentional violation (asserting
//! diagnostic name and file:line), suppression and exemptions behave, and —
//! the real gate — the repo's own tree lints clean.

use hift_lint::{e1_count, lint_source, lint_tree};
use std::path::Path;

fn repo_root() -> &'static Path {
    // tools/hift-lint/tests -> repo root is two levels above the manifest.
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/../.."))
}

/// (lint, line) pairs of all findings for a fixture linted under `rel`.
fn findings(rel: &str, src: &str) -> Vec<(String, usize)> {
    lint_source(rel, src).into_iter().map(|f| (f.lint, f.line)).collect()
}

#[test]
fn d1_fma_fixture() {
    let src = include_str!("../fixtures/d1_fma.rs");
    let fs = findings("rust/src/backend/kernels/fixture.rs", src);
    assert_eq!(fs, vec![("fma".to_string(), 7)]);
    // Same code outside the D1 scope is clean.
    assert!(findings("rust/src/metrics/fixture.rs", src).is_empty());
}

#[test]
fn d2_hash_iteration_fixture() {
    let src = include_str!("../fixtures/d2_hash_iter.rs");
    let fs = findings("rust/src/backend/fixture.rs", src);
    assert_eq!(fs, vec![("hash-iteration".to_string(), 12)]);
}

#[test]
fn d3_timing_taint_fixture() {
    let src = include_str!("../fixtures/d3_taint.rs");
    let fs = findings("rust/src/backend/fixture.rs", src);
    // Line 16: plain tainted chain.  Line 25: the taint crossed a braced
    // `move ||` closure binding (the historical false negative).  The
    // marker-named `bench_probe` closure stays a sanctioned sink.
    assert_eq!(
        fs,
        vec![("timing-taint".to_string(), 16), ("timing-taint".to_string(), 25)]
    );
}

#[test]
fn d4_float_reduction_fixture() {
    let src = include_str!("../fixtures/d4_reduction.rs");
    let fs = findings("rust/src/optim/fixture.rs", src);
    assert_eq!(
        fs,
        vec![("float-reduction".to_string(), 6), ("float-reduction".to_string(), 10)]
    );
    // The kernel layer owns its reduction order: same code is exempt there.
    assert!(findings("rust/src/backend/kernels/fixture.rs", src).is_empty());
}

#[test]
fn c1_budget_lease_fixture() {
    let src = include_str!("../fixtures/c1_spawn.rs");
    let fs = findings("rust/src/optim/fixture.rs", src);
    assert_eq!(fs, vec![("budget-lease".to_string(), 6)]);
}

#[test]
fn e1_count_fixture() {
    let src = include_str!("../fixtures/e1_unwrap.rs");
    assert_eq!(e1_count(src), 3);
}

#[test]
fn unjustified_tag_is_a_finding_and_does_not_suppress() {
    let src = "fn f(v: &[f32]) -> f32 {\n    // hift-lint: allow(float-reduction)\n    v.iter().sum::<f32>()\n}\n";
    let fs = findings("rust/src/optim/fixture.rs", src);
    assert_eq!(
        fs,
        vec![("bad-allow-tag".to_string(), 2), ("float-reduction".to_string(), 3)]
    );
}

/// The acceptance gate in miniature: the repo's own tree must produce zero
/// findings with the checked-in E1 baseline.
#[test]
fn repo_tree_is_clean() {
    let report = lint_tree(repo_root(), false).expect("lint_tree walks rust/src");
    let rendered: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(rendered.is_empty(), "repo tree has findings:\n{}", rendered.join("\n"));
    assert!(report.files_checked > 20, "walked only {} files", report.files_checked);
}
