//! hift-lint — determinism & concurrency contract linter for the `hift`
//! crate, run as `cargo xtask lint` from the repo root.
//!
//! The repo's headline claims (bit-identical group sweeps across kernel
//! schedules, worker counts, checkpoint policies, and kill+resume) rest on
//! written-but-unchecked invariants.  This crate checks the static half of
//! each one; the `contracts` feature of the `hift` crate checks the dynamic
//! half at runtime.  `docs/CONTRACTS.md` is the map between the two.
//!
//! The analysis is a self-contained token-level lexer (`lex`), not an AST:
//! the offline vendor set has no `syn`, so the lints trade a little
//! precision for zero dependencies.  Each lint is documented in `lints` with
//! exactly what it matches.

pub mod lex;
pub mod lints;

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One lint diagnostic, rendered as `error[{lint}] {file}:{line}: {msg}`.
#[derive(Debug, Clone)]
pub struct Finding {
    pub lint: String,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "error[{}] {}:{}: {}", self.lint, self.file, self.line, self.msg)
    }
}

/// Lint one file's source. `rel` is the repo-relative path with forward
/// slashes — lint scoping keys off it.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    lints::lint_file(rel, &lex::FileLex::new(src))
}

/// E1 count for one file's source (library-path unwrap/expect/panic sites).
pub fn e1_count(src: &str) -> usize {
    lints::e1_count(&lex::FileLex::new(src))
}

/// Result of linting the whole tree.
pub struct Report {
    pub findings: Vec<Finding>,
    /// Non-fatal notes (e.g. an E1 count dropped below its baseline).
    pub warnings: Vec<String>,
    pub files_checked: usize,
}

const BASELINE_REL: &str = "tools/hift-lint/e1-baseline.txt";

/// Lint every `.rs` file under `<root>/rust/src`, in sorted order, and apply
/// the E1 ratchet against `<root>/tools/hift-lint/e1-baseline.txt`.
///
/// With `write_baseline`, the baseline file is rewritten from the current
/// counts (nonzero entries only) instead of being enforced.
pub fn lint_tree(root: &Path, write_baseline: bool) -> io::Result<Report> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)?;
    files.sort();

    let baseline = read_baseline(&root.join(BASELINE_REL))?;
    let mut findings = Vec::new();
    let mut warnings = Vec::new();
    let mut counts: BTreeMap<String, usize> = BTreeMap::new();

    for path in &files {
        let rel = rel_path(root, path);
        let src = fs::read_to_string(path)?;
        findings.extend(lint_source(&rel, &src));
        let n = e1_count(&src);
        counts.insert(rel.clone(), n);
        if write_baseline {
            continue;
        }
        let base = baseline.get(&rel).copied().unwrap_or(0);
        if n > base {
            findings.push(Finding {
                lint: "e1-ratchet".into(),
                file: rel.clone(),
                line: 0,
                msg: format!(
                    "{n} unwrap/expect/panic site(s) on library paths exceeds the ratchet baseline of {base}; \
                     convert to Result (the baseline only goes down — see {BASELINE_REL})"
                ),
            });
        } else if n < base {
            warnings.push(format!(
                "{rel}: E1 count dropped {base} -> {n}; run `cargo xtask lint --write-baseline` to ratchet the baseline down"
            ));
        }
    }

    if write_baseline {
        let mut out = String::from(
            "# E1 ratchet baseline: library-path unwrap/expect/panic sites per file.\n\
             # Counts may only decrease. Regenerate with: cargo xtask lint --write-baseline\n",
        );
        for (rel, n) in &counts {
            if *n > 0 {
                out.push_str(&format!("{n} {rel}\n"));
            }
        }
        fs::write(root.join(BASELINE_REL), out)?;
    }

    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(Report { findings, warnings, files_checked: files.len() })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if !dir.is_dir() {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("source root {} not found — run from the repo root or pass --root", dir.display()),
        ));
    }
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<io::Result<_>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    // Normalize to forward slashes so lint scoping is platform-independent.
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

fn read_baseline(path: &Path) -> io::Result<BTreeMap<String, usize>> {
    let mut map = BTreeMap::new();
    let text = match fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(map), // treated as all-zero
        Err(e) => return Err(e),
    };
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.splitn(2, ' ');
        let (n, rel) = (it.next().unwrap_or(""), it.next().unwrap_or("").trim());
        match n.parse::<usize>() {
            Ok(n) if !rel.is_empty() => {
                map.insert(rel.to_string(), n);
            }
            _ => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("{}:{}: malformed baseline line `{line}`", path.display(), i + 1),
                ));
            }
        }
    }
    Ok(map)
}
