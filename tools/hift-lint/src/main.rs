//! CLI for hift-lint.  Invoked as `cargo xtask lint [--root <dir>]
//! [--write-baseline]` (the alias lives in `.cargo/config.toml`), or as
//! `cargo xtask plancheck [flags]`, which delegates to
//! `hift plancheck` — the static schedule & memory-model verifier — so the
//! static analyses share one CI entry point.
//!
//! Exit codes: 0 clean, 1 findings, 2 usage or I/O error.

use std::env;
use std::path::PathBuf;
use std::process::ExitCode;

fn usage() -> ExitCode {
    eprintln!(
        "usage: cargo xtask lint [--root <repo-root>] [--write-baseline]\n       \
         cargo xtask plancheck [--preset P] [--steps N] [--out FILE] [--inject KIND]"
    );
    ExitCode::from(2)
}

/// Delegate `cargo xtask plancheck ...` to the hift binary (`hift
/// plancheck`), passing every flag through verbatim.
fn run_plancheck(extra: Vec<String>) -> ExitCode {
    let status = std::process::Command::new(env::var("CARGO").unwrap_or_else(|_| "cargo".into()))
        .args(["run", "--quiet", "--release", "-p", "hift", "--", "plancheck"])
        .args(&extra)
        .status();
    match status {
        Ok(s) if s.success() => ExitCode::SUCCESS,
        Ok(_) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("hift-lint: launching `hift plancheck` failed: {e}");
            ExitCode::from(2)
        }
    }
}

fn main() -> ExitCode {
    let mut args = env::args().skip(1);
    match args.next().as_deref() {
        Some("lint") => {}
        Some("plancheck") => return run_plancheck(args.collect()),
        _ => return usage(),
    }
    let mut root: Option<PathBuf> = None;
    let mut write_baseline = false;
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(r) => root = Some(PathBuf::from(r)),
                None => return usage(),
            },
            "--write-baseline" => write_baseline = true,
            _ => return usage(),
        }
    }
    let root = root.unwrap_or_else(|| {
        // `cargo xtask lint` runs with the invoker's cwd; fall back to the
        // workspace root derived from this crate's manifest dir.
        if PathBuf::from("rust/src").is_dir() {
            PathBuf::from(".")
        } else {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..")
        }
    });

    let report = match hift_lint::lint_tree(&root, write_baseline) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("hift-lint: {e}");
            return ExitCode::from(2);
        }
    };
    for w in &report.warnings {
        eprintln!("warning: {w}");
    }
    for f in &report.findings {
        println!("{f}");
    }
    if write_baseline {
        println!("hift-lint: baseline rewritten from {} file(s)", report.files_checked);
        return ExitCode::SUCCESS;
    }
    if report.findings.is_empty() {
        println!("hift-lint: {} file(s) clean", report.files_checked);
        ExitCode::SUCCESS
    } else {
        println!("hift-lint: {} finding(s) across {} file(s)", report.findings.len(), report.files_checked);
        ExitCode::FAILURE
    }
}
