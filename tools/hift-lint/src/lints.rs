//! The six contract lints.  Each is a token-level pass over a [`FileLex`];
//! see `docs/CONTRACTS.md` for the invariant each one guards and the runtime
//! assertion that backs it.
//!
//! | name              | contract                                                    |
//! |-------------------|-------------------------------------------------------------|
//! | `fma`             | D1: no `mul_add` in kernel/model/shard reduction code       |
//! | `hash-iteration`  | D2: no hash-order iteration in emit-order-sensitive modules |
//! | `timing-taint`    | D3: clock values only flow into timing/throughput sinks     |
//! | `float-reduction` | D4: float reductions confined to kernels + `tree_fold`      |
//! | `budget-lease`    | C1: every spawn site leases from `ThreadBudget` in-function |
//! | `e1-ratchet`      | E1: library-path `unwrap`/`expect`/`panic!` only decreases  |
//!
//! Findings on `#[cfg(test)]` lines are dropped (tests are exempt), and any
//! finding except `bad-allow-tag`/`e1-ratchet` can be suppressed by a
//! justified `// hift-lint: allow(<name>): <why>` tag on the same or the
//! preceding line.

use crate::lex::{FileLex, Tok};
use crate::Finding;
use std::collections::HashSet;

/// Lint names a `hift-lint: allow(...)` tag may reference.
pub const SUPPRESSIBLE: &[&str] =
    &["fma", "hash-iteration", "timing-taint", "float-reduction", "budget-lease"];

const ASSIGN_OPS: &[&str] = &["=", "+=", "-=", "*=", "/="];

fn is(t: Option<&Tok>, s: &str) -> bool {
    t.is_some_and(|t| t.s == s)
}

/// Run every lint over one file. `rel` is the repo-relative path with
/// forward slashes (e.g. `rust/src/backend/model.rs`) — several lints are
/// scoped by path.
pub fn lint_file(rel: &str, lex: &FileLex) -> Vec<Finding> {
    let mut out = Vec::new();
    bad_allow_tags(rel, lex, &mut out);
    d1_fma(rel, lex, &mut out);
    d2_hash_iteration(rel, lex, &mut out);
    d3_timing_taint(rel, lex, &mut out);
    d4_float_reduction(rel, lex, &mut out);
    c1_budget_lease(rel, lex, &mut out);
    // Drop test-region findings, then honor justified allow tags.
    out.retain(|f| !lex.line_is_test(f.line));
    out.retain(|f| f.lint == "bad-allow-tag" || !lex.allowed(&f.lint, f.line));
    out.sort_by(|a, b| (a.line, &a.lint).cmp(&(b.line, &b.lint)));
    out.dedup_by(|a, b| a.line == b.line && a.lint == b.lint);
    out
}

fn push(out: &mut Vec<Finding>, lint: &str, rel: &str, line: usize, msg: String) {
    out.push(Finding { lint: lint.to_string(), file: rel.to_string(), line, msg });
}

/// A malformed tag is itself a finding — an allowlist nobody can audit is
/// worse than no allowlist.
fn bad_allow_tags(rel: &str, lex: &FileLex, out: &mut Vec<Finding>) {
    for t in &lex.tags {
        if !SUPPRESSIBLE.contains(&t.lint.as_str()) {
            push(out, "bad-allow-tag", rel, t.line,
                format!("unknown lint `{}` in allow tag (known: {})", t.lint, SUPPRESSIBLE.join(", ")));
        } else if !t.justified {
            push(out, "bad-allow-tag", rel, t.line,
                format!("allow({}) tag has no justification — write `// hift-lint: allow({}): <why>`", t.lint, t.lint));
        }
    }
}

// ---------------------------------------------------------------------------
// D1 — no FMA in reduction code
// ---------------------------------------------------------------------------

fn d1_in_scope(rel: &str) -> bool {
    rel.contains("backend/kernels/")
        || rel.ends_with("backend/model.rs")
        || rel.ends_with("backend/shard.rs")
}

fn d1_fma(rel: &str, lex: &FileLex, out: &mut Vec<Finding>) {
    if !d1_in_scope(rel) {
        return;
    }
    for t in &lex.toks {
        if t.ident && t.s == "mul_add" {
            push(out, "fma", rel, t.line,
                "mul_add fuses rounding and breaks cross-schedule bit-identity; use separate mul + add".into());
        }
    }
}

// ---------------------------------------------------------------------------
// D2 — no hash-order iteration in emit-order-sensitive modules
// ---------------------------------------------------------------------------

fn d2_in_scope(rel: &str) -> bool {
    rel.contains("/backend/")
        || rel.contains("/optim/")
        || rel.contains("/ser/")
        || rel.contains("/data/")
        || rel.ends_with("tensor/paged.rs")
}

const ITER_METHODS: &[&str] = &[
    "iter", "iter_mut", "keys", "values", "values_mut", "drain", "into_iter", "into_keys",
    "into_values", "retain",
];

fn d2_hash_iteration(rel: &str, lex: &FileLex, out: &mut Vec<Finding>) {
    if !d2_in_scope(rel) {
        return;
    }
    let toks = &lex.toks;
    // Pass 1: a per-file symbol table of names that hold a HashMap/HashSet —
    // type aliases, `name: HashMap<..>` declarations (params, fields, lets),
    // and `name = HashMap::new()` style constructions.
    let mut aliases: HashSet<&str> = HashSet::new();
    let mut vars: HashSet<&str> = HashSet::new();
    let is_hash = |s: &str, aliases: &HashSet<&str>| {
        s == "HashMap" || s == "HashSet" || aliases.contains(s)
    };
    for i in 0..toks.len() {
        // `type Name = ... HashMap ... ;`
        if toks[i].ident && toks[i].s == "type" {
            if let (Some(name), true) = (toks.get(i + 1), is(toks.get(i + 2), "=")) {
                let mut j = i + 3;
                while j < toks.len() && toks[j].s != ";" {
                    if toks[j].ident && (toks[j].s == "HashMap" || toks[j].s == "HashSet") {
                        aliases.insert(name.s.as_str());
                        break;
                    }
                    j += 1;
                }
            }
        }
        // `name : [& ' mut std::collections::] HashMap<..>`
        if toks[i].ident && is(toks.get(i + 1), ":") {
            let mut j = i + 2;
            while j < toks.len() {
                match toks[j].s.as_str() {
                    "&" | "'" | "mut" | "::" | "std" | "collections" => j += 1,
                    _ => break,
                }
            }
            if toks.get(j).is_some_and(|t| t.ident && is_hash(&t.s, &aliases)) {
                vars.insert(toks[i].s.as_str());
            }
        }
        // `name = HashMap::...` (covers `let [mut] name = HashMap::new()`)
        if toks[i].ident
            && is(toks.get(i + 1), "=")
            && toks.get(i + 2).is_some_and(|t| t.ident && is_hash(&t.s, &aliases))
        {
            vars.insert(toks[i].s.as_str());
        }
    }
    // Pass 2: flag order-dependent consumption of those names.
    for i in 0..toks.len() {
        // `name.iter()` and friends
        if toks[i].ident
            && ITER_METHODS.contains(&toks[i].s.as_str())
            && is(toks.get(i + 1), "(")
            && i >= 2
            && toks[i - 1].s == "."
            && vars.contains(toks[i - 2].s.as_str())
        {
            push(out, "hash-iteration", rel, toks[i].line,
                format!("`{}.{}()` iterates in hash order in an emit-order-sensitive module; use BTreeMap or tag with a justification", toks[i - 2].s, toks[i].s));
        }
        // `for pat in <expr containing a hash var> {`
        if toks[i].ident && toks[i].s == "for" {
            let in_pos = (i + 1..toks.len().min(i + 40)).find(|&j| toks[j].ident && toks[j].s == "in");
            if let Some(ip) = in_pos {
                let mut j = ip + 1;
                let mut hit: Option<&Tok> = None;
                let mut ranged = false;
                while j < toks.len() && toks[j].s != "{" && j < ip + 60 {
                    if toks[j].s == ".." {
                        ranged = true;
                    }
                    if toks[j].ident && vars.contains(toks[j].s.as_str()) {
                        hit = Some(&toks[j]);
                    }
                    j += 1;
                }
                if let (Some(v), false) = (hit, ranged) {
                    push(out, "hash-iteration", rel, toks[i].line,
                        format!("for-loop over hash collection `{}` in an emit-order-sensitive module; use BTreeMap or tag with a justification", v.s));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// D3 — timing taint
// ---------------------------------------------------------------------------

/// Identifier fragments that mark a sanctioned timing sink (counters,
/// durations, throughput).  Short markers (`t0`, `t1`, `dt`) must match a
/// whole underscore-delimited word to avoid hitting e.g. `width`.
const MARKERS: &[&str] = &[
    "nano", "micro", "milli", "sec", "time", "elapsed", "stall", "throughput", "gflops", "rate",
    "start", "t0", "t1", "dt", "dur", "wall", "clock", "tick", "deadline", "stamp", "bench",
    "prof",
];

fn has_marker(ident: &str) -> bool {
    let l = ident.to_ascii_lowercase();
    MARKERS.iter().any(|m| {
        if m.len() <= 2 {
            l == *m || l.starts_with(&format!("{m}_")) || l.ends_with(&format!("_{m}"))
        } else {
            l.contains(m)
        }
    })
}

struct FnSpan {
    name: String,
    start: usize,
    end: usize,
}

/// Token spans of every `fn` body, plus the innermost enclosing fn of each
/// token.  Brace-depth based; `;` before the body brace cancels a pending
/// header (trait method declarations), ignoring `;` inside `[u8; 4]`-style
/// signature types.
fn fn_spans(toks: &[Tok]) -> (Vec<FnSpan>, Vec<Option<usize>>) {
    let mut spans: Vec<FnSpan> = Vec::new();
    let mut fn_of: Vec<Option<usize>> = vec![None; toks.len()];
    let mut stack: Vec<(usize, usize)> = Vec::new(); // (span idx, body depth)
    let mut depth = 0usize;
    let mut pending: Option<(String, usize)> = None;
    let mut sig_nest = 0isize; // () / [] nesting inside a pending signature
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.ident && t.s == "fn" {
            if let Some(n) = toks.get(i + 1) {
                if n.ident {
                    pending = Some((n.s.clone(), i));
                    sig_nest = 0;
                }
            }
        } else if pending.is_some() && (t.s == "(" || t.s == "[") {
            sig_nest += 1;
        } else if pending.is_some() && (t.s == ")" || t.s == "]") {
            sig_nest -= 1;
        } else if t.s == ";" && sig_nest == 0 {
            pending = None;
        } else if t.s == "{" {
            depth += 1;
            if let Some((name, start)) = pending.take() {
                spans.push(FnSpan { name, start, end: toks.len().saturating_sub(1) });
                stack.push((spans.len() - 1, depth));
            }
        } else if t.s == "}" {
            if let Some(&(si, bd)) = stack.last() {
                if bd == depth {
                    spans[si].end = i;
                    stack.pop();
                }
            }
            depth = depth.saturating_sub(1);
        }
        fn_of[i] = stack.last().map(|&(si, _)| si);
    }
    (spans, fn_of)
}

fn d3_timing_taint(rel: &str, lex: &FileLex, out: &mut Vec<Finding>) {
    let toks = &lex.toks;
    let (spans, fn_of) = fn_spans(toks);
    for (si, sp) in spans.iter().enumerate() {
        // A function that is itself a timing utility is a sink end-to-end.
        if has_marker(&sp.name) {
            continue;
        }
        // Walk the whole span so brace depth stays balanced across nested
        // items, but only this fn's own tokens (nested fns are analyzed on
        // their own) join statements and closure-body scans.
        let mut taint: HashSet<String> = HashSet::new();
        let mut stmt: Vec<usize> = Vec::new();
        // Open braced-closure bindings: (bound names, body depth, body start).
        let mut closures: Vec<(Vec<String>, usize, usize)> = Vec::new();
        let mut depth = 0usize;
        for i in sp.start..=sp.end.min(toks.len() - 1) {
            let s = toks[i].s.as_str();
            if s == "{" {
                depth += 1;
                if let Some(names) = d3_closure_binding(toks, &stmt) {
                    closures.push((names, depth, i + 1));
                }
                d3_statement(rel, toks, &stmt, &mut taint, out);
                stmt.clear();
            } else if s == "}" {
                if closures.last().is_some_and(|&(_, d, _)| d == depth) {
                    // The braced closure body closes.  Taint must survive the
                    // `|..|` edge: if anything inside read the clock or a
                    // tainted name, the binding carries it from here on.
                    if let Some((names, _, start)) = closures.pop() {
                        let body: Vec<usize> =
                            (start..i).filter(|&j| fn_of[j] == Some(si)).collect();
                        if d3_rhs_tainted(toks, &body, &taint) {
                            taint.extend(names);
                        }
                    }
                }
                depth = depth.saturating_sub(1);
                d3_statement(rel, toks, &stmt, &mut taint, out);
                stmt.clear();
            } else if s == ";" {
                d3_statement(rel, toks, &stmt, &mut taint, out);
                stmt.clear();
            } else if fn_of[i] == Some(si) {
                stmt.push(i);
            }
        }
        d3_statement(rel, toks, &stmt, &mut taint, out);
    }
}

/// `let name = … |…| {` — a braced-closure binding whose body is about to
/// open.  Returns the bound names, or `None` when the statement isn't a
/// closure binding or the name is a marker (a sanctioned sink, same rule as
/// plain `let`).  Requiring the statement to *end* on a `|` / `||` token
/// keeps bitwise-or rhs (`let x = a | B { .. }`) out.
fn d3_closure_binding(toks: &[Tok], stmt: &[usize]) -> Option<Vec<String>> {
    let (&first, &last) = (stmt.first()?, stmt.last()?);
    if !(toks[first].ident && toks[first].s == "let") {
        return None;
    }
    if toks[last].s != "|" && toks[last].s != "||" {
        return None;
    }
    let eq = stmt.iter().position(|&i| toks[i].s == "=")?;
    let lhs = &stmt[..eq];
    if lhs.iter().any(|&i| toks[i].ident && has_marker(&toks[i].s)) {
        return None;
    }
    let names: Vec<String> = lhs
        .iter()
        .skip(1)
        .filter(|&&i| toks[i].ident && toks[i].s != "mut")
        .map(|&i| toks[i].s.clone())
        .collect();
    if names.is_empty() { None } else { Some(names) }
}

fn d3_rhs_tainted(toks: &[Tok], rhs: &[usize], taint: &HashSet<String>) -> bool {
    for (k, &i) in rhs.iter().enumerate() {
        let t = &toks[i];
        if !t.ident {
            continue;
        }
        if t.s == "Instant" || t.s == "SystemTime" {
            return true;
        }
        if t.s == "elapsed" && k > 0 && toks[rhs[k - 1]].s == "." {
            return true;
        }
        if taint.contains(&t.s) {
            return true;
        }
    }
    false
}

fn d3_statement(
    rel: &str,
    toks: &[Tok],
    stmt: &[usize],
    taint: &mut HashSet<String>,
    out: &mut Vec<Finding>,
) {
    if stmt.is_empty() {
        return;
    }
    let eq = match stmt.iter().position(|&i| ASSIGN_OPS.contains(&toks[i].s.as_str())) {
        Some(p) => p,
        None => return,
    };
    let (lhs, rhs) = (&stmt[..eq], &stmt[eq + 1..]);
    if !d3_rhs_tainted(toks, rhs, taint) {
        return;
    }
    let head = &toks[stmt[0]];
    if head.ident && head.s == "let" {
        // Marker-named binding is a sanctioned sink: taint terminates there.
        // Otherwise the new name silently joins the taint set.
        if !lhs.iter().any(|&i| toks[i].ident && has_marker(&toks[i].s)) {
            for &i in lhs.iter().skip(1) {
                if toks[i].ident && toks[i].s != "mut" {
                    taint.insert(toks[i].s.clone());
                }
            }
        }
        return;
    }
    // Plain assignment (`x = ...`, `x += ...`): only statements headed by an
    // identifier count — `if`, `while`, `return`, `match` heads are reads.
    const HEADS_SKIP: &[&str] = &["if", "while", "match", "for", "return", "else", "loop"];
    if !head.ident || HEADS_SKIP.contains(&head.s.as_str()) {
        return;
    }
    if lhs.iter().any(|&i| toks[i].ident && has_marker(&toks[i].s)) {
        return;
    }
    push(out, "timing-taint", rel, head.line,
        "clock-derived value assigned into computed state; route timing through a *_nanos/throughput-named sink".into());
}

// ---------------------------------------------------------------------------
// D4 — float reductions confined to the kernel layer
// ---------------------------------------------------------------------------

fn d4_exempt(rel: &str) -> bool {
    rel.contains("backend/kernels/") || rel.ends_with("backend/shard.rs")
}

fn d4_float_reduction(rel: &str, lex: &FileLex, out: &mut Vec<Finding>) {
    if d4_exempt(rel) {
        return;
    }
    let toks = &lex.toks;
    for i in 0..toks.len() {
        if toks[i].ident
            && toks[i].s == "sum"
            && is(toks.get(i + 1), "::")
            && is(toks.get(i + 2), "<")
            && toks.get(i + 3).is_some_and(|t| t.s == "f32")
        {
            push(out, "float-reduction", rel, toks[i].line,
                ".sum::<f32>() outside the kernel layer; reduction order must be owned by kernels/shard::tree_fold".into());
        }
        if toks[i].ident
            && toks[i].s == "fold"
            && i > 0
            && toks[i - 1].s == "."
            && is(toks.get(i + 1), "(")
        {
            push(out, "float-reduction", rel, toks[i].line,
                "raw .fold() reduction outside the kernel layer; use a kernel primitive or shard::tree_fold, or tag with a justification".into());
        }
    }
}

// ---------------------------------------------------------------------------
// C1 — spawn sites must lease from ThreadBudget in the same function
// ---------------------------------------------------------------------------

fn c1_budget_lease(rel: &str, lex: &FileLex, out: &mut Vec<Finding>) {
    let toks = &lex.toks;
    let (spans, fn_of) = fn_spans(toks);
    for i in 0..toks.len() {
        if !(toks[i].ident && toks[i].s == "spawn" && is(toks.get(i + 1), "(")) {
            continue;
        }
        if i > 0 && toks[i - 1].s == "fn" {
            continue; // a fn named `spawn`, not a call site
        }
        let leased = fn_of[i].is_some_and(|si| {
            let sp = &spans[si];
            toks[sp.start..=sp.end.min(toks.len() - 1)]
                .iter()
                .any(|t| t.ident && matches!(t.s.as_str(), "lease" | "register_worker" | "ThreadBudget"))
        });
        if !leased {
            push(out, "budget-lease", rel, toks[i].line,
                "spawn site without a ThreadBudget lease/register_worker in the same function (oversubscription hazard)".into());
        }
    }
}

// ---------------------------------------------------------------------------
// E1 — unwrap/expect/panic ratchet
// ---------------------------------------------------------------------------

/// Count library-path `.unwrap(` / `.expect(` / `panic!` sites (test regions
/// excluded).  The count per file is compared against
/// `tools/hift-lint/e1-baseline.txt` and may only go down.
pub fn e1_count(lex: &FileLex) -> usize {
    let toks = &lex.toks;
    let mut n = 0usize;
    for i in 0..toks.len() {
        if lex.line_is_test(toks[i].line) {
            continue;
        }
        if toks[i].ident
            && (toks[i].s == "unwrap" || toks[i].s == "expect")
            && i > 0
            && toks[i - 1].s == "."
            && is(toks.get(i + 1), "(")
        {
            n += 1;
        }
        if toks[i].ident && toks[i].s == "panic" && is(toks.get(i + 1), "!") {
            n += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lex::FileLex;

    fn lint(rel: &str, src: &str) -> Vec<Finding> {
        lint_file(rel, &FileLex::new(src))
    }

    #[test]
    fn d1_only_fires_in_scope() {
        let src = "fn f(a: f32, b: f32, c: f32) -> f32 { a.mul_add(b, c) }\n";
        assert_eq!(lint("rust/src/backend/model.rs", src).len(), 1);
        assert_eq!(lint("rust/src/metrics/mod.rs", src).len(), 0);
    }

    #[test]
    fn d2_tracks_aliases_and_for_loops() {
        let src = "use std::collections::HashMap;\ntype Slots = HashMap<String, u64>;\nfn f(slots: &Slots) {\n    for (k, v) in slots {\n        let _ = (k, v);\n    }\n}\n";
        let fs = lint("rust/src/backend/native.rs", src);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].lint, "hash-iteration");
        assert_eq!(fs[0].line, 4);
    }

    #[test]
    fn d2_covers_the_data_forge() {
        let src = "use std::collections::HashSet;\nfn f(seen: &HashSet<u64>) {\n    for h in seen {\n        let _ = h;\n    }\n}\n";
        let fs = lint("rust/src/data/quality.rs", src);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].lint, "hash-iteration");
        // BTreeMap iteration (the forge's label histogram) stays clean.
        let ordered = "use std::collections::BTreeMap;\nfn g(m: &BTreeMap<i32, u64>) {\n    for (k, v) in m {\n        let _ = (k, v);\n    }\n}\n";
        assert!(lint("rust/src/data/quality.rs", ordered).is_empty());
    }

    #[test]
    fn d2_ignores_ranges_and_lookups() {
        let src = "use std::collections::HashMap;\nfn f(m: &HashMap<u32, u32>) -> Option<&u32> {\n    for i in 0..m.len() { let _ = i; }\n    m.get(&3)\n}\n";
        assert!(lint("rust/src/backend/native.rs", src).is_empty());
    }

    #[test]
    fn d3_marker_let_terminates_taint() {
        let clean = "fn f() {\n    let t0 = Instant::now();\n    let secs = t0.elapsed().as_secs_f64();\n    let gflops = work / secs;\n    naive = gflops;\n}\n";
        assert!(lint("rust/src/bench/exhibits.rs", clean).is_empty());
        let dirty = "fn f() {\n    let x = Instant::now().elapsed().as_secs_f64();\n    let y = x * 2.0;\n    weight = y;\n}\n";
        let fs = lint("rust/src/bench/exhibits.rs", dirty);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].lint, "timing-taint");
        assert_eq!(fs[0].line, 4);
    }

    #[test]
    fn d3_taint_crosses_closure_boundaries() {
        // The braced body reads the clock, so the binding (and everything
        // derived from calling it) is clock-tainted.
        let dirty = "fn f(weights: &mut [f32]) {\n    let probe = move || {\n        Instant::now().elapsed().as_secs_f64()\n    };\n    let v = probe();\n    weights[1] = v as f32;\n}\n";
        let fs = lint("rust/src/bench/exhibits.rs", dirty);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].lint, "timing-taint");
        assert_eq!(fs[0].line, 6);
        // Marker-named closure bindings stay sanctioned sinks, and a
        // bitwise-or rhs with a struct literal is not a closure.
        let clean = "fn f(w: &mut [f32]) {\n    let bench_probe = move || { Instant::now().elapsed().as_secs_f64() };\n    let x = bench_probe();\n    let _ = x;\n    let flags = BASE | Flags { raw: 1 }.raw;\n    w[0] = flags as f32;\n}\n";
        assert!(lint("rust/src/bench/exhibits.rs", clean).is_empty());
    }

    #[test]
    fn d4_exempts_kernels() {
        let src = "fn f(v: &[f32]) -> f32 { v.iter().sum::<f32>() }\n";
        assert_eq!(lint("rust/src/optim/adafactor.rs", src).len(), 1);
        assert!(lint("rust/src/backend/kernels/gemm.rs", src).is_empty());
        assert!(lint("rust/src/backend/shard.rs", src).is_empty());
    }

    #[test]
    fn c1_requires_in_function_lease() {
        let bad = "fn f() { std::thread::spawn(|| {}); }\n";
        let fs = lint("rust/src/tensor/paged.rs", bad);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].lint, "budget-lease");
        let good = "fn f() { let slot = par::register_worker(); std::thread::spawn(|| {}); }\n";
        assert!(lint("rust/src/tensor/paged.rs", good).is_empty());
    }

    #[test]
    fn allow_tag_suppresses_and_bad_tag_fires() {
        let tagged = "fn f(v: &[f32]) -> f32 {\n    // hift-lint: allow(float-reduction): sequential, fixed order\n    v.iter().sum::<f32>()\n}\n";
        assert!(lint("rust/src/optim/adafactor.rs", tagged).is_empty());
        let bad = "// hift-lint: allow(no-such-lint): whatever\nfn f() {}\n";
        let fs = lint("rust/src/optim/adafactor.rs", bad);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].lint, "bad-allow-tag");
    }

    #[test]
    fn e1_counts_library_sites_only() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\nfn g() { panic!(\"boom\") }\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { Some(1).unwrap(); }\n}\n";
        assert_eq!(e1_count(&FileLex::new(src)), 2);
    }
}
