//! A small, honest Rust lexer: enough structure to run token-level contract
//! lints, and nothing more.
//!
//! The offline vendor set has no `syn`/`proc-macro2`, so this is a
//! hand-rolled scanner rather than an AST.  It understands exactly what the
//! lints need:
//!
//! * comments (line, nested block) and string/char literals are stripped so
//!   they can never produce false tokens — newlines are preserved so every
//!   token keeps its 1-based line number;
//! * `// hift-lint: allow(<lint>): <justification>` tags are extracted from
//!   line comments *before* stripping;
//! * `#[cfg(test)]` item regions are brace-matched so test code is exempt
//!   from library-path lints;
//! * multi-char operators (`::`, `==`, `=>`, `+=`, `..`, …) come out as
//!   single tokens so `=` unambiguously means assignment.

/// One `// hift-lint: allow(name): justification` tag.  A tag covers its
/// own line and the line directly below it.
#[derive(Debug, Clone)]
pub struct AllowTag {
    pub line: usize,
    pub lint: String,
    /// The justification text after `):` was present and non-empty.
    pub justified: bool,
}

/// A lexed token: its text, 1-based line, and whether it is an identifier.
#[derive(Debug, Clone)]
pub struct Tok {
    pub s: String,
    pub line: usize,
    pub ident: bool,
}

/// The lexed view of one source file.
pub struct FileLex {
    /// Source with comments and string/char literals blanked to spaces
    /// (newlines kept, so byte offsets map to the original lines).
    pub code: String,
    pub toks: Vec<Tok>,
    pub tags: Vec<AllowTag>,
    /// `in_test[line]` (1-based; index 0 unused) — line sits inside a
    /// `#[cfg(test)]` item's braces.
    pub in_test: Vec<bool>,
}

impl FileLex {
    pub fn new(src: &str) -> FileLex {
        let (code, tags) = strip(src);
        let toks = tokenize(&code);
        let in_test = test_regions(&code);
        FileLex { code, toks, tags, in_test }
    }

    pub fn line_is_test(&self, line: usize) -> bool {
        self.in_test.get(line).copied().unwrap_or(false)
    }

    /// Is a finding of `lint` on `line` covered by a justified allow tag?
    pub fn allowed(&self, lint: &str, line: usize) -> bool {
        self.tags
            .iter()
            .any(|t| t.justified && t.lint == lint && (t.line == line || t.line + 1 == line))
    }
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_char(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Blank comments and string/char literals; collect allow tags.
fn strip(src: &str) -> (String, Vec<AllowTag>) {
    let b = src.as_bytes();
    let mut out = vec![b' '; b.len()];
    let mut tags = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            out[i] = b'\n';
            line += 1;
            i += 1;
            continue;
        }
        // Line comment — scan to end of line, look for an allow tag.
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            if let Some(tag) = parse_tag(&src[start..i], line) {
                tags.push(tag);
            }
            continue;
        }
        // Block comment — Rust block comments nest.
        if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1usize;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'\n' {
                    out[i] = b'\n';
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // Raw strings: r"..." / r#"..."# / br#"..."# (any # count).
        if (c == b'r' || (c == b'b' && b.get(i + 1) == Some(&b'r')))
            && (i == 0 || !is_ident_char(b[i - 1]))
        {
            let mut j = i + if c == b'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while b.get(j) == Some(&b'#') {
                hashes += 1;
                j += 1;
            }
            if b.get(j) == Some(&b'"') {
                j += 1;
                'raw: while j < b.len() {
                    if b[j] == b'\n' {
                        out[j] = b'\n';
                        line += 1;
                        j += 1;
                    } else if b[j] == b'"' {
                        let mut k = j + 1;
                        let mut seen = 0usize;
                        while seen < hashes && b.get(k) == Some(&b'#') {
                            seen += 1;
                            k += 1;
                        }
                        if seen == hashes {
                            j = k;
                            break 'raw;
                        }
                        j += 1;
                    } else {
                        j += 1;
                    }
                }
                i = j;
                continue;
            }
            // Not a raw string — fall through and emit the ident char.
        }
        // Plain / byte strings.
        if c == b'"' || (c == b'b' && b.get(i + 1) == Some(&b'"') && (i == 0 || !is_ident_char(b[i - 1])))
        {
            i += if c == b'b' { 2 } else { 1 };
            while i < b.len() {
                if b[i] == b'\n' {
                    out[i] = b'\n';
                    line += 1;
                    i += 1;
                } else if b[i] == b'\\' {
                    i += 2;
                } else if b[i] == b'"' {
                    i += 1;
                    break;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // Char literal vs lifetime.  `'\...'` and `'x'` are chars; `'ident`
        // with no closing quote right after is a lifetime.
        if c == b'\'' {
            let is_char = match b.get(i + 1) {
                Some(&b'\\') => true,
                Some(&n) if is_ident_char(n) => b.get(i + 2) == Some(&b'\''),
                Some(_) => true, // e.g. '(' — only valid as a char literal
                None => false,
            };
            if is_char {
                i += 1;
                while i < b.len() {
                    if b[i] == b'\\' {
                        i += 2;
                    } else if b[i] == b'\'' {
                        i += 1;
                        break;
                    } else {
                        if b[i] == b'\n' {
                            out[i] = b'\n';
                            line += 1;
                        }
                        i += 1;
                    }
                }
                continue;
            }
            // Lifetime: keep the quote (tokenizer skips it as punct).
        }
        out[i] = c;
        i += 1;
    }
    (String::from_utf8(out).expect("blanking preserves utf-8 structure"), tags)
}

/// Parse `hift-lint: allow(name)[: justification]` out of a line comment.
fn parse_tag(comment: &str, line: usize) -> Option<AllowTag> {
    let idx = comment.find("hift-lint:")?;
    let rest = comment[idx + "hift-lint:".len()..].trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let close = rest.find(')')?;
    let lint = rest[..close].trim().to_string();
    let after = rest[close + 1..].trim_start();
    let justified = after
        .strip_prefix(':')
        .map(|j| !j.trim().is_empty())
        .unwrap_or(false);
    Some(AllowTag { line, lint, justified })
}

const MULTI_OPS: &[&str] =
    &["::", "->", "=>", "==", "!=", "<=", ">=", "+=", "-=", "*=", "/=", "..", "&&", "||"];

fn tokenize(code: &str) -> Vec<Tok> {
    let b = code.as_bytes();
    let mut toks = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        if is_ident_start(c) {
            let start = i;
            while i < b.len() && is_ident_char(b[i]) {
                i += 1;
            }
            toks.push(Tok { s: code[start..i].to_string(), line, ident: true });
            continue;
        }
        if c.is_ascii_digit() {
            // Numeric literal (incl. suffixes like 1.0f32, 0x1f, 1_000u64):
            // one opaque token so suffixes never masquerade as identifiers.
            let start = i;
            while i < b.len() && (is_ident_char(b[i]) || b[i] == b'.') {
                // Stop a `0..n` range from being eaten as one number.
                if b[i] == b'.' && b.get(i + 1) == Some(&b'.') {
                    break;
                }
                i += 1;
            }
            toks.push(Tok { s: code[start..i].to_string(), line, ident: false });
            continue;
        }
        if let Some(op) = MULTI_OPS.iter().find(|op| code[i..].starts_with(**op)) {
            toks.push(Tok { s: op.to_string(), line, ident: false });
            i += op.len();
            continue;
        }
        toks.push(Tok { s: (c as char).to_string(), line, ident: false });
        i += 1;
    }
    toks
}

/// Mark every line inside a `#[cfg(test)]` item's braces.
fn test_regions(code: &str) -> Vec<bool> {
    let n_lines = code.bytes().filter(|&c| c == b'\n').count() + 2;
    let mut in_test = vec![false; n_lines];
    let b = code.as_bytes();
    let line_of = |pos: usize| 1 + code[..pos].bytes().filter(|&c| c == b'\n').count();
    let mut from = 0usize;
    while let Some(rel) = code[from..].find("#[cfg(test)]") {
        let attr = from + rel;
        // Find the item's opening brace (skipping further attributes and
        // the `mod name` header); bail at a `;` (e.g. `mod tests;`).
        let mut i = attr + "#[cfg(test)]".len();
        let mut open = None;
        while i < b.len() {
            match b[i] {
                b'{' => {
                    open = Some(i);
                    break;
                }
                b';' => break,
                _ => i += 1,
            }
        }
        if let Some(open) = open {
            let mut depth = 0usize;
            let mut j = open;
            while j < b.len() {
                match b[j] {
                    b'{' => depth += 1,
                    b'}' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            let (a, z) = (line_of(attr), line_of(j.min(b.len().saturating_sub(1))));
            for l in a..=z.min(n_lines - 1) {
                in_test[l] = true;
            }
            from = j.min(b.len());
        } else {
            from = attr + 1;
        }
        if from >= b.len() {
            break;
        }
    }
    in_test
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_strings_and_chars() {
        let lex = FileLex::new(
            "let a = \"mul_add\"; // mul_add\nlet b = 'x'; /* mul_add /* nested */ */ let c = r#\"mul_add\"#;\n",
        );
        assert!(!lex.toks.iter().any(|t| t.s == "mul_add"));
        assert_eq!(lex.toks.iter().filter(|t| t.s == "let").count(), 3);
    }

    #[test]
    fn lifetimes_are_not_chars() {
        let lex = FileLex::new("fn f<'a>(x: &'a str) -> &'a str { x }\n");
        assert!(lex.toks.iter().any(|t| t.s == "str"));
        assert!(lex.toks.iter().any(|t| t.s == "{"));
    }

    #[test]
    fn allow_tags_parse_and_require_justification() {
        let lex = FileLex::new(
            "// hift-lint: allow(fma): fixture needs it\nx.mul_add(y, z);\n// hift-lint: allow(fma)\ny.mul_add(y, z);\n",
        );
        assert_eq!(lex.tags.len(), 2);
        assert!(lex.allowed("fma", 2), "tag on line 1 covers line 2");
        assert!(!lex.allowed("fma", 4), "unjustified tag does not allow");
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn lib2() {}\n";
        let lex = FileLex::new(src);
        assert!(!lex.line_is_test(1));
        assert!(lex.line_is_test(4));
        assert!(!lex.line_is_test(6));
    }

    #[test]
    fn multi_char_ops_fuse() {
        let lex = FileLex::new("a += b; c == d; e => f; g.. ; h::i\n");
        let ops: Vec<_> = lex.toks.iter().filter(|t| !t.ident).map(|t| t.s.as_str()).collect();
        assert!(ops.contains(&"+="));
        assert!(ops.contains(&"=="));
        assert!(ops.contains(&"=>"));
        assert!(ops.contains(&".."));
        assert!(ops.contains(&"::"));
        assert!(!ops.contains(&"="));
    }
}
