"""L1 correctness gate: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; every kernel must match ``ref`` to
tolerance on both the forward value and (via custom_vjp) its gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, layernorm, softmax_xent, ref
from compile.kernels.flash_attention import pick_blocks, vmem_estimate

jax.config.update("jax_platform_name", "cpu")

RTOL, ATOL = 2e-5, 2e-5


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------- attention

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([4, 8, 16, 32, 48]),
    dh=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_attention_matches_ref(b, h, s, dh, causal, seed):
    q = rand(seed, (b, h, s, dh))
    k = rand(seed + 1, (b, h, s, dh))
    v = rand(seed + 2, (b, h, s, dh))
    got = attention(q, k, v, causal)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_attention_grads_match_ref():
    q, k, v = rand(0, (2, 2, 16, 8)), rand(1, (2, 2, 16, 8)), rand(2, (2, 2, 16, 8))

    def f_kernel(q, k, v):
        return jnp.sum(attention(q, k, v, True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=True) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_attention_causality():
    """Future tokens must not influence earlier outputs."""
    q, k, v = rand(0, (1, 1, 16, 8)), rand(1, (1, 1, 16, 8)), rand(2, (1, 1, 16, 8))
    base = attention(q, k, v, True)
    k2 = k.at[0, 0, -1].set(99.0)
    v2 = v.at[0, 0, -1].set(-99.0)
    pert = attention(q, k2, v2, True)
    np.testing.assert_allclose(base[0, 0, :-1], pert[0, 0, :-1], rtol=RTOL, atol=ATOL)
    assert not np.allclose(base[0, 0, -1], pert[0, 0, -1])


def test_pick_blocks_divides():
    for s in (4, 16, 30, 48, 80, 128, 384):
        bq, bkv = pick_blocks(s, 8)
        assert s % bq == 0 and s % bkv == 0


def test_vmem_estimate_fits():
    rep = vmem_estimate(8, 8, 512, 64)
    assert rep["fits_16MiB_vmem"]
    assert rep["bytes_per_program"] > 0


# ----------------------------------------------------------------------- ce

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([2, 4, 8, 32, 64]),
    v=st.sampled_from([8, 32, 64, 512]),
    seed=st.integers(0, 10_000),
)
def test_ce_matches_ref(n, v, seed):
    logits = rand(seed, (n, v)) * 3.0
    tgt = jax.random.randint(jax.random.PRNGKey(seed + 7), (n,), 0, v)
    np.testing.assert_allclose(
        softmax_xent(logits, tgt), ref.softmax_xent_ref(logits, tgt), rtol=1e-5, atol=1e-5
    )


def test_ce_grad_is_softmax_minus_onehot():
    logits = rand(3, (8, 16))
    tgt = jax.random.randint(jax.random.PRNGKey(9), (8,), 0, 16)
    g = jax.grad(lambda l: jnp.sum(softmax_xent(l, tgt)))(logits)
    want = jax.nn.softmax(logits, -1) - jax.nn.one_hot(tgt, 16)
    np.testing.assert_allclose(g, want, rtol=1e-5, atol=1e-5)


def test_ce_extreme_logits_stable():
    logits = jnp.array([[1e4, -1e4, 0.0, 3.0]] * 4, jnp.float32)
    tgt = jnp.array([0, 1, 2, 3], jnp.int32)
    out = softmax_xent(logits, tgt)
    assert np.all(np.isfinite(out))
    np.testing.assert_allclose(out, ref.softmax_xent_ref(logits, tgt), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- layernorm

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([1, 2, 8, 32, 96]),
    d=st.sampled_from([8, 16, 64, 128]),
    seed=st.integers(0, 10_000),
)
def test_layernorm_matches_ref(n, d, seed):
    x = rand(seed, (n, d)) * 2.0 + 0.5
    scale = rand(seed + 1, (d,)) * 0.1 + 1.0
    bias = rand(seed + 2, (d,)) * 0.1
    np.testing.assert_allclose(
        layernorm(x, scale, bias), ref.layernorm_ref(x, scale, bias), rtol=1e-5, atol=1e-5
    )


def test_layernorm_3d_and_grads():
    x = rand(0, (2, 4, 16))
    s, b = jnp.ones(16), jnp.zeros(16)
    np.testing.assert_allclose(
        layernorm(x, s, b), ref.layernorm_ref(x, s, b), rtol=1e-5, atol=1e-5
    )
    gk = jax.grad(lambda x: jnp.sum(layernorm(x, s, b) ** 2))(x)
    gr = jax.grad(lambda x: jnp.sum(ref.layernorm_ref(x, s, b) ** 2))(x)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-4)


def test_layernorm_output_normalized():
    x = rand(5, (8, 32)) * 7 + 3
    y = layernorm(x, jnp.ones(32), jnp.zeros(32))
    np.testing.assert_allclose(np.mean(np.asarray(y), -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.std(np.asarray(y), -1), 1.0, atol=1e-2)
