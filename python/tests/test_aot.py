"""AOT pipeline tests: manifest integrity, params.bin layout, HLO-text
round-trip through XlaComputation (the exact interchange Rust consumes)."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "tiny")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_manifest_schema(manifest):
    assert manifest["schema"] == 1
    assert manifest["preset"] == "tiny"
    cfg = manifest["config"]
    assert manifest["n_units"] == cfg["n_layers"] + 2
    assert set(manifest["variants"]) == {"base", "lora", "ia3", "prefix"}


def test_manifest_units_partition_base_params(manifest):
    base = manifest["variants"]["base"]["params"]
    units = {p["unit"] for p in base}
    assert units == set(range(manifest["n_units"]))
    # offsets are ascending and distinct per tensor
    offsets = [p["offset"] for p in base]
    assert offsets == sorted(offsets)
    assert len(set(offsets)) == len(offsets)


def test_params_bin_matches_manifest_sizes(manifest):
    base = manifest["variants"]["base"]["params"]
    total_bytes = sum(p["size"] * 4 for p in base)
    assert os.path.getsize(os.path.join(ART, "params.bin")) == total_bytes
    last = base[-1]
    assert last["offset"] + last["size"] * 4 == total_bytes


def test_params_bin_roundtrips_init(manifest):
    cfg = M.PRESETS["tiny"]
    specs = M.param_specs(cfg)
    params = M.init_params(cfg, specs, seed=manifest["seed"])
    raw = open(os.path.join(ART, "params.bin"), "rb").read()
    for sp, arr, info in zip(specs, params, manifest["variants"]["base"]["params"]):
        got = np.frombuffer(raw, dtype="<f4", count=sp.size, offset=info["offset"])
        np.testing.assert_array_equal(got, np.asarray(arr).reshape(-1), err_msg=sp.name)


def test_every_artifact_inputs_are_params_plus_batch(manifest):
    for art in manifest["artifacts"]:
        variant = art["name"].split("_")[1]
        params = manifest["variants"][variant]["params"]
        names = [p["name"] for p in params]
        assert art["inputs"] == names + ["tokens", "targets", "weights"], art["name"]
        assert art["outputs"][:2] == ["loss", "ncorrect"]
        # grad outputs must reference real parameters
        for g in art["outputs"][2:]:
            assert g in names, f"{art['name']}: {g}"


def test_hlo_text_parses_back_to_xla_computation(manifest):
    from jax._src.lib import xla_client as xc

    path = os.path.join(ART, manifest["artifacts"][0]["path"])
    text = open(path).read()
    assert text.startswith("HloModule"), "artifact must be HLO text, not a serialized proto"
    # jax's bundled XLA can re-parse the text — same parser family the
    # xla crate uses via HloModuleProto::from_text_file.
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_lower_fn_is_deterministic():
    cfg = M.PRESETS["tiny"]
    specs, fwd, _ = M.make_fns(cfg, "base", use_pallas=False)
    a = aot.lower_fn(fwd, specs, cfg)
    b = aot.lower_fn(fwd, specs, cfg)
    assert a == b


def test_vmem_report_present(manifest):
    rep = manifest["vmem_report"]
    assert rep["bytes_per_program"] > 0
    assert rep["fits_16MiB_vmem"] is True
