"""L2 model tests: shapes, unit partition, per-unit grads == full grads,
variant behaviour, pallas-vs-ref lowering parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(name="t", vocab=32, d_model=16, n_layers=2, n_heads=2,
                    d_ff=32, seq_len=8, batch=2, lora_rank=2, n_prefix=4)


def make_batch(seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (CFG.batch, CFG.seq_len), 0, CFG.vocab)
    targets = jnp.roll(tokens, -1, axis=1)
    weights = jnp.ones((CFG.batch, CFG.seq_len), jnp.float32)
    return tokens, targets, weights


def flat_params(variant="base", seed=0):
    specs = M.param_specs(CFG) + M.adapter_specs(CFG, variant)
    return specs, M.init_params(CFG, specs, seed=seed)


# ------------------------------------------------------------------- specs

def test_unit_partition_covers_all_params():
    specs = M.param_specs(CFG)
    units = {sp.unit for sp in specs}
    assert units == set(range(CFG.n_units))
    # embeddings first, head last
    assert specs[0].unit == 0 and specs[-1].unit == CFG.n_units - 1


def test_param_count_formula():
    specs = M.param_specs(CFG)
    total = sum(sp.size for sp in specs)
    d, f, v, s, p = CFG.d_model, CFG.d_ff, CFG.vocab, CFG.seq_len, CFG.n_prefix
    per_layer = 4 * d * d + 4 * d + 2 * d * f + f + d + 4 * d
    want = v * d + (s + p) * d + CFG.n_layers * per_layer + 2 * d + d * v + v
    assert total == want


def test_bitfit_marks_only_vectors():
    for sp in M.param_specs(CFG):
        if sp.bitfit:
            assert len(sp.shape) == 1


@pytest.mark.parametrize("variant,nadapter", [("lora", 8), ("ia3", 6), ("prefix", 1)])
def test_adapter_specs(variant, nadapter):
    ads = M.adapter_specs(CFG, variant)
    assert len(ads) == nadapter
    assert all(sp.unit == -1 for sp in ads)


# ----------------------------------------------------------------- forward

@pytest.mark.parametrize("variant", ["base", "lora", "ia3", "prefix"])
@pytest.mark.parametrize("use_pallas", [True, False])
def test_forward_finite(variant, use_pallas):
    specs, fwd, _ = M.make_fns(CFG, variant, use_pallas)
    params = M.init_params(CFG, specs)
    loss, ncorrect = fwd(*params, *make_batch())
    assert np.isfinite(loss) and loss > 0
    assert 0 <= ncorrect <= CFG.batch * CFG.seq_len


@pytest.mark.parametrize("variant", ["base", "lora", "prefix"])
def test_pallas_ref_parity(variant):
    """The two kernel paths must lower to the same numbers."""
    specs, fwd_p, _ = M.make_fns(CFG, variant, True)
    _, fwd_r, _ = M.make_fns(CFG, variant, False)
    params = M.init_params(CFG, specs)
    batch = make_batch()
    lp, cp = fwd_p(*params, *batch)
    lr, cr = fwd_r(*params, *batch)
    np.testing.assert_allclose(lp, lr, rtol=5e-5, atol=5e-5)
    assert cp == cr


def test_lora_zero_b_is_identity():
    """LoRA with B=0 must equal the base model exactly."""
    specs, fwd, _ = M.make_fns(CFG, "lora", False)
    params = M.init_params(CFG, specs)  # b-matrices init to zeros
    _, fwd_base, _ = M.make_fns(CFG, "base", False)
    base_params = params[: len(M.param_specs(CFG))]
    batch = make_batch()
    np.testing.assert_allclose(fwd(*params, *batch)[0], fwd_base(*base_params, *batch)[0],
                               rtol=1e-6, atol=1e-6)


def test_ia3_ones_is_identity():
    specs, fwd, _ = M.make_fns(CFG, "ia3", False)
    params = M.init_params(CFG, specs)  # ia3 scales init to ones
    _, fwd_base, _ = M.make_fns(CFG, "base", False)
    base_params = params[: len(M.param_specs(CFG))]
    batch = make_batch()
    np.testing.assert_allclose(fwd(*params, *batch)[0], fwd_base(*base_params, *batch)[0],
                               rtol=1e-6, atol=1e-6)


def test_weights_mask_selects_positions():
    """Loss with a one-position mask equals that position's NLL."""
    specs, fwd, _ = M.make_fns(CFG, "base", False)
    params = M.init_params(CFG, specs)
    tokens, targets, _ = make_batch()
    w = jnp.zeros((CFG.batch, CFG.seq_len)).at[:, -1].set(1.0)
    loss_last, _ = fwd(*params, tokens, targets, w)
    loss_all, _ = fwd(*params, tokens, targets, jnp.ones_like(w))
    assert not np.allclose(loss_last, loss_all)
    assert np.isfinite(loss_last)


# ------------------------------------------------------------------- grads

def test_unit_grads_concat_equals_full_grad():
    """HiFT's foundation: per-unit gradients are *slices* of the full
    gradient (same loss, same point), so composing units reconstructs FPFT's
    gradient exactly."""
    specs, _, factory = M.make_fns(CFG, "base", False)
    params = M.init_params(CFG, specs)
    batch = make_batch()
    full = factory(list(range(len(specs))))(*params, *batch)
    full_grads = full[2:]
    for u in range(CFG.n_units):
        idxs = [i for i, sp in enumerate(specs) if sp.unit == u]
        out = factory(idxs)(*params, *batch)
        np.testing.assert_allclose(out[0], full[0], rtol=1e-5, atol=1e-5)
        for j, i in enumerate(idxs):
            np.testing.assert_allclose(out[2 + j], full_grads[i], rtol=1e-4, atol=1e-5,
                                       err_msg=specs[i].name)


def test_grad_descent_step_reduces_loss():
    specs, fwd, factory = M.make_fns(CFG, "base", False)
    params = M.init_params(CFG, specs)
    batch = make_batch()
    out = factory(list(range(len(specs))))(*params, *batch)
    loss0, grads = out[0], out[2:]
    new = [p - 0.1 * g for p, g in zip(params, grads)]
    loss1, _ = fwd(*new, *batch)
    assert loss1 < loss0


def test_adapter_grads_nonzero_lora():
    specs, _, factory = M.make_fns(CFG, "lora", False)
    params = M.init_params(CFG, specs)
    idxs = [i for i, sp in enumerate(specs) if sp.unit == -1]
    out = factory(idxs)(*params, *make_batch())
    grads = out[2:]
    # A-grads are zero at init only if B==0 kills the path; B-grads nonzero.
    bnorm = sum(float(jnp.abs(g).sum()) for g, i in zip(grads, idxs)
                if ".b" in specs[i].name)
    assert bnorm > 0


def test_grad_wrt_single_unit_is_cheaper_graph():
    """Backprop truncation: grad of the head unit must not touch tok_emb's
    gradient at all (it is never an output)."""
    specs, _, factory = M.make_fns(CFG, "base", False)
    head_idxs = [i for i, sp in enumerate(specs) if sp.unit == CFG.n_units - 1]
    g = factory(head_idxs)
    out = g(*M.init_params(CFG, specs), *make_batch())
    assert len(out) == 2 + len(head_idxs)
