"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness gate).

Every Pallas kernel in this package has an exact mathematical twin here,
written with plain ``jax.numpy`` ops only.  ``python/tests`` sweeps shapes
and dtypes asserting ``assert_allclose(kernel, ref)``.  The L2 model can be
lowered against either implementation (``--kernels=ref|pallas``) so the
numerical agreement of the two paths is itself testable end to end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative used for causal masking (f32-safe)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
) -> jax.Array:
    """Scaled dot-product attention, optionally causal.

    Args:
      q, k, v: ``[B, H, S, Dh]``.
      causal: apply lower-triangular mask.

    Returns:
      ``[B, H, S, Dh]`` attention output.
    """
    *_, s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def layernorm_ref(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    """LayerNorm over the last axis. x: [..., D]; scale/bias: [D]."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    return (x - mean) * inv * scale + bias


def softmax_xent_ref(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Per-row softmax cross-entropy.

    Args:
      logits: ``[N, V]``.
      targets: ``[N]`` int32 class ids.

    Returns:
      ``[N]`` negative log-likelihood per row.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return logz - gold


def gelu_ref(x: jax.Array) -> jax.Array:
    """tanh-approximation GELU (matches the kernel's polynomial)."""
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=x.dtype))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))
