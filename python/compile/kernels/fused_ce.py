"""L1 Pallas kernel: fused softmax cross-entropy.

Computes per-row NLL of ``logits [N, V]`` against ``targets [N]`` without
materializing the ``[N, V]`` softmax: the grid walks row-blocks and each
program streams the vocabulary in ``blk_v`` VMEM tiles with an online
logsumexp, extracting the gold logit on the fly.  This is the memory shape
that matters on TPU — the HiFT training loss over a 32k vocab would
otherwise allocate a second logits-sized buffer.

Backward is supplied analytically via ``jax.custom_vjp``:
``d nll / d logits = softmax(logits) - onehot(target)`` (recomputed, not
stored), scaled by the incoming cotangent.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ce_kernel(logits_ref, targets_ref, nll_ref, *, blk_v: int):
    """One program: NLL for a block of rows.

    Refs:
      logits_ref: [blk_n, V]
      targets_ref: [blk_n]
      nll_ref: [blk_n]
    """
    blk_n, v = logits_ref.shape
    n_v = v // blk_v
    tgt = targets_ref[...]

    def body(j, carry):
        m_prev, l_prev, gold_prev = carry
        tile = pl.load(logits_ref, (slice(None), pl.ds(j * blk_v, blk_v))).astype(jnp.float32)
        m_cur = jnp.max(tile, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        l_new = l_prev * jnp.exp(m_prev - m_new) + jnp.sum(jnp.exp(tile - m_new[:, None]), axis=-1)
        # Gold logit if the target lands in this vocab tile.
        cols = j * blk_v + jax.lax.broadcasted_iota(jnp.int32, (blk_n, blk_v), 1)
        hit = cols == tgt[:, None]
        gold_new = gold_prev + jnp.sum(jnp.where(hit, tile, 0.0), axis=-1)
        return m_new, l_new, gold_new

    m0 = jnp.full((blk_n,), -1e30, dtype=jnp.float32)
    l0 = jnp.zeros((blk_n,), dtype=jnp.float32)
    g0 = jnp.zeros((blk_n,), dtype=jnp.float32)
    m, l, gold = jax.lax.fori_loop(0, n_v, body, (m0, l0, g0))
    nll_ref[...] = (m + jnp.log(l) - gold).astype(nll_ref.dtype)


def _pick_blocks(n: int, v: int):
    def best(total, target):
        cand = [b for b in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1) if total % b == 0 and b <= target]
        return cand[0] if cand else 1

    return best(n, 64), best(v, 512)


def _ce_fwd_pallas(logits, targets):
    n, v = logits.shape
    blk_n, blk_v = _pick_blocks(n, v)
    kernel = functools.partial(_ce_kernel, blk_v=blk_v)
    return pl.pallas_call(
        kernel,
        grid=(n // blk_n,),
        in_specs=[
            pl.BlockSpec((blk_n, v), lambda i: (i, 0)),
            pl.BlockSpec((blk_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(logits, targets.astype(jnp.int32))


@jax.custom_vjp
def softmax_xent(logits, targets):
    """Per-row softmax cross-entropy; Pallas forward, analytic backward."""
    return _ce_fwd_pallas(logits, targets)


def _ce_vjp_fwd(logits, targets):
    return _ce_fwd_pallas(logits, targets), (logits, targets)


def _ce_vjp_bwd(res, g):
    logits, targets = res
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=jnp.float32)
    dlogits = (probs - onehot) * g[:, None]
    return dlogits.astype(logits.dtype), None


softmax_xent.defvjp(_ce_vjp_fwd, _ce_vjp_bwd)
