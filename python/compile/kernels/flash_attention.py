"""L1 Pallas kernel: tiled (flash-style) causal attention.

TPU rethink of the GPU training hot-spot (DESIGN.md §Hardware-Adaptation):
instead of CUDA threadblocks staging tiles through shared memory, the
HBM->VMEM schedule is expressed with ``BlockSpec``s — the grid walks
``(batch*heads, q-blocks)`` and each program streams the K/V sequence in
``blk_kv``-sized VMEM tiles with an online-softmax accumulator, so the
``[S, S]`` score matrix is never materialized.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO through the Pallas
interpreter.  Real-TPU VMEM/MXU characteristics are *estimated* from the
block shapes (``vmem_estimate``) and recorded in DESIGN.md, not measured.

The backward pass is supplied by ``jax.custom_vjp`` against the exact
reference math (``ref.attention_ref``): the recomputation-based flash
backward adds nothing numerically and the interpreter gives it no speed
advantage, while keeping the fwd artifact Pallas-tiled end to end.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = ref.NEG_INF


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, blk_kv: int, causal: bool, q_offset_blocks: int):
    """One grid program: attend one q-block against all kv-blocks.

    Refs (VMEM views selected by the BlockSpecs below):
      q_ref: [1, blk_q, dh]   the active query tile
      k_ref: [1, S, dh]       full key sequence for this (batch, head)
      v_ref: [1, S, dh]       full value sequence
      o_ref: [1, blk_q, dh]   output tile
    """
    blk_q, dh = q_ref.shape[1], q_ref.shape[2]
    s = k_ref.shape[1]
    n_kv = s // blk_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))

    q = q_ref[0].astype(jnp.float32) * scale  # [blk_q, dh]
    qi = pl.program_id(1)
    q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_kv), 0)

    def body(j, carry):
        acc, m_prev, l_prev = carry
        k = pl.load(k_ref, (0, pl.ds(j * blk_kv, blk_kv), slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (0, pl.ds(j * blk_kv, blk_kv), slice(None))).astype(jnp.float32)
        logits = q @ k.T  # [blk_q, blk_kv] — MXU tile on real hardware
        if causal:
            kv_pos = j * blk_kv + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_kv), 1)
            logits = jnp.where(q_pos >= kv_pos, logits, NEG_INF)
        # Online softmax: fold this tile into the running (max, sum, acc).
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((blk_q, dh), dtype=jnp.float32)
    m0 = jnp.full((blk_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((blk_q,), dtype=jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def _attention_fwd_pallas(q, k, v, *, blk_q: int, blk_kv: int, causal: bool):
    b, h, s, dh = q.shape
    bh = b * h
    qf = q.reshape(bh, s, dh)
    kf = k.reshape(bh, s, dh)
    vf = v.reshape(bh, s, dh)
    n_q = s // blk_q
    kernel = functools.partial(
        _attn_kernel, blk_kv=blk_kv, causal=causal, q_offset_blocks=0
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, n_q),
        in_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, s, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, s, dh)


def pick_blocks(s: int, dh: int) -> Tuple[int, int]:
    """Choose (blk_q, blk_kv) dividing S, sized for a ~128-lane VMEM tile."""

    def best(target: int) -> int:
        cand = [b for b in (128, 64, 32, 16, 8, 4, 2, 1) if s % b == 0 and b <= target]
        return cand[0] if cand else 1

    return best(128), best(128)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, causal: bool = True):
    """Flash-style causal attention; Pallas forward, reference-math backward."""
    blk_q, blk_kv = pick_blocks(q.shape[2], q.shape[3])
    return _attention_fwd_pallas(q, k, v, blk_q=blk_q, blk_kv=blk_kv, causal=causal)


def _attention_vjp_fwd(q, k, v, causal):
    out = attention(q, k, v, causal)
    return out, (q, k, v)


def _attention_vjp_bwd(causal, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=causal), q, k, v)
    return vjp(g)


attention.defvjp(_attention_vjp_fwd, _attention_vjp_bwd)


def vmem_estimate(b: int, h: int, s: int, dh: int, dtype_bytes: int = 4) -> dict:
    """Static VMEM-footprint estimate for one grid program (DESIGN.md §Perf).

    Returns bytes held in VMEM simultaneously: q tile, one kv tile pair,
    accumulator + softmax stats, output tile.  Used to verify the block
    choice fits a 16 MiB TPU VMEM with double-buffering headroom.
    """
    blk_q, blk_kv = pick_blocks(s, dh)
    q_tile = blk_q * dh * dtype_bytes
    kv_tile = 2 * blk_kv * dh * dtype_bytes
    acc = blk_q * dh * 4 + 2 * blk_q * 4  # f32 accumulator + m/l stats
    out = blk_q * dh * dtype_bytes
    total = q_tile + 2 * kv_tile + acc + out  # x2 kv: double buffering
    return {
        "blk_q": blk_q,
        "blk_kv": blk_kv,
        "bytes_per_program": total,
        "fits_16MiB_vmem": total < 16 * 1024 * 1024 // 2,
        "mxu_tile_aligned": blk_q % 128 == 0 and dh % 128 == 0,
    }
