"""L1 Pallas kernel: LayerNorm over the last axis.

Grid walks row-blocks of the flattened ``[N, D]`` input; each program
normalizes its rows in one VMEM tile (mean/variance in f32 regardless of
input dtype).  Forward is Pallas; backward comes from ``jax.custom_vjp``
against the reference math so grad artifacts stay interpreter-friendly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _ln_kernel(x_ref, scale_ref, bias_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [blk_n, D]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = (x - mean) * inv * scale_ref[...].astype(jnp.float32) + bias_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _pick_blk(n: int) -> int:
    for b in (128, 64, 32, 16, 8, 4, 2, 1):
        if n % b == 0:
            return b
    return 1


def _ln_fwd_pallas(x2d, scale, bias, eps: float):
    n, d = x2d.shape
    blk_n = _pick_blk(n)
    import functools

    kernel = functools.partial(_ln_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // blk_n,),
        in_specs=[
            pl.BlockSpec((blk_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x2d.dtype),
        interpret=True,
    )(x2d, scale, bias)


@jax.custom_vjp
def layernorm(x, scale, bias):
    """LayerNorm over the last axis; Pallas forward, reference backward."""
    shp = x.shape
    y = _ln_fwd_pallas(x.reshape(-1, shp[-1]), scale, bias, 1e-5)
    return y.reshape(shp)


def _ln_vjp_fwd(x, scale, bias):
    return layernorm(x, scale, bias), (x, scale, bias)


def _ln_vjp_bwd(res, g):
    x, scale, bias = res
    _, vjp = jax.vjp(lambda x_, s_, b_: ref.layernorm_ref(x_, s_, b_), x, scale, bias)
    return vjp(g)


layernorm.defvjp(_ln_vjp_fwd, _ln_vjp_bwd)
