"""L1 Pallas kernels (build-time only; lowered into the L2 HLO artifacts)."""

from . import ref  # noqa: F401
from .flash_attention import attention, vmem_estimate  # noqa: F401
from .fused_ce import softmax_xent  # noqa: F401
from .layernorm import layernorm  # noqa: F401
