"""L2: the JAX transformer LM whose fwd/bwd HiFT schedules (build-time only).

A decoder-only, pre-LN transformer with learned positions and an untied LM
head.  Attention / layernorm / cross-entropy call the L1 Pallas kernels
(``--kernels=pallas``) or their pure-jnp oracles (``--kernels=ref``) — the
two lowerings must agree numerically, which ``python/tests`` asserts.

Parameters are an ordered flat list of named f32 tensors partitioned into
**layer units** exactly as the paper prescribes (§F "Implementation
Details"): all embeddings are one unit, each transformer block is one unit,
and the head (final LN + LM head) is one unit.  ``aot.py`` lowers one
gradient artifact *per unit* (``jax.grad`` w.r.t. that subset only, so XLA
truncates backprop below the deepest active layer — the §4.3 speed effect);
the Rust coordinator composes units into groups of ``m`` at run time.

PEFT baselines the paper compares against are separate *variants* of the
same graph with extra adapter inputs:
  - ``lora``:   rank-r updates on W_q / W_v   (Hu et al., 2022)
  - ``ia3``:    learned rescaling of K / V / FFN hidden (Liu et al., 2022)
  - ``prefix``: trainable virtual-token embeddings   (Lester et al., 2021)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref as kref

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture + batch geometry (baked into each artifact)."""

    name: str = "tiny"
    vocab: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    d_ff: int = 128
    seq_len: int = 32
    batch: int = 4
    # PEFT variant knobs
    lora_rank: int = 4
    lora_alpha: float = 8.0
    n_prefix: int = 16

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_units(self) -> int:
        """Layer units: embeddings + each block + head (paper §F)."""
        return self.n_layers + 2

    def to_json_dict(self) -> dict:
        return dataclasses.asdict(self)


PRESETS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(name="tiny", vocab=64, d_model=32, n_layers=2, n_heads=2,
                        d_ff=64, seq_len=16, batch=4, lora_rank=2, n_prefix=4),
    "small": ModelConfig(name="small", vocab=256, d_model=128, n_layers=4, n_heads=4,
                         d_ff=256, seq_len=64, batch=8, lora_rank=4, n_prefix=16),
    "base": ModelConfig(name="base", vocab=512, d_model=256, n_layers=6, n_heads=8,
                        d_ff=1024, seq_len=64, batch=8, lora_rank=8, n_prefix=16),
    "e2e": ModelConfig(name="e2e", vocab=4096, d_model=512, n_layers=8, n_heads=8,
                       d_ff=2048, seq_len=64, batch=8, lora_rank=8, n_prefix=16),
    "e2e100m": ModelConfig(name="e2e100m", vocab=32768, d_model=768, n_layers=12,
                           n_heads=12, d_ff=3072, seq_len=128, batch=4,
                           lora_rank=8, n_prefix=16),
}


# --------------------------------------------------------------------------
# Parameter specification
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    unit: int          # layer-unit index (0=embed, 1..L=blocks, L+1=head)
    init: str          # "normal" | "zeros" | "ones"
    bitfit: bool = False  # updated by the BitFit baseline (biases + LN params)

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


def param_specs(cfg: ModelConfig) -> List[ParamSpec]:
    """Ordered flat parameter list; order == artifact input order."""
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    out: List[ParamSpec] = [
        ParamSpec("tok_emb", (v, d), 0, "normal"),
        ParamSpec("pos_emb", (s + cfg.n_prefix, d), 0, "normal"),
    ]
    for i in range(cfg.n_layers):
        u = i + 1
        p = f"l{i}."
        out += [
            ParamSpec(p + "ln1.scale", (d,), u, "ones", bitfit=True),
            ParamSpec(p + "ln1.bias", (d,), u, "zeros", bitfit=True),
            ParamSpec(p + "attn.wq", (d, d), u, "normal"),
            ParamSpec(p + "attn.bq", (d,), u, "zeros", bitfit=True),
            ParamSpec(p + "attn.wk", (d, d), u, "normal"),
            ParamSpec(p + "attn.bk", (d,), u, "zeros", bitfit=True),
            ParamSpec(p + "attn.wv", (d, d), u, "normal"),
            ParamSpec(p + "attn.bv", (d,), u, "zeros", bitfit=True),
            ParamSpec(p + "attn.wo", (d, d), u, "normal"),
            ParamSpec(p + "attn.bo", (d,), u, "zeros", bitfit=True),
            ParamSpec(p + "ln2.scale", (d,), u, "ones", bitfit=True),
            ParamSpec(p + "ln2.bias", (d,), u, "zeros", bitfit=True),
            ParamSpec(p + "ffn.w1", (d, f), u, "normal"),
            ParamSpec(p + "ffn.b1", (f,), u, "zeros", bitfit=True),
            ParamSpec(p + "ffn.w2", (f, d), u, "normal"),
            ParamSpec(p + "ffn.b2", (d,), u, "zeros", bitfit=True),
        ]
    u = cfg.n_layers + 1
    out += [
        ParamSpec("ln_f.scale", (d,), u, "ones", bitfit=True),
        ParamSpec("ln_f.bias", (d,), u, "zeros", bitfit=True),
        ParamSpec("head.w", (d, v), u, "normal"),
        ParamSpec("head.b", (v,), u, "zeros", bitfit=True),
    ]
    return out


def adapter_specs(cfg: ModelConfig, variant: str) -> List[ParamSpec]:
    """Extra trainable inputs for PEFT variants (unit = -1: 'adapter')."""
    d, f, r = cfg.d_model, cfg.d_ff, cfg.lora_rank
    out: List[ParamSpec] = []
    if variant == "lora":
        for i in range(cfg.n_layers):
            p = f"l{i}.lora."
            out += [
                ParamSpec(p + "aq", (d, r), -1, "normal"),
                ParamSpec(p + "bq", (r, d), -1, "zeros"),
                ParamSpec(p + "av", (d, r), -1, "normal"),
                ParamSpec(p + "bv", (r, d), -1, "zeros"),
            ]
    elif variant == "ia3":
        for i in range(cfg.n_layers):
            p = f"l{i}.ia3."
            out += [
                ParamSpec(p + "lk", (d,), -1, "ones"),
                ParamSpec(p + "lv", (d,), -1, "ones"),
                ParamSpec(p + "lff", (f,), -1, "ones"),
            ]
    elif variant == "prefix":
        out.append(ParamSpec("prefix.emb", (cfg.n_prefix, d), -1, "normal"))
    elif variant == "base":
        pass
    else:
        raise ValueError(f"unknown variant {variant!r}")
    return out


def init_params(cfg: ModelConfig, specs: Sequence[ParamSpec], seed: int = 0) -> List[Array]:
    """Deterministic init (fan-in-scaled normal / zeros / ones)."""
    key = jax.random.PRNGKey(seed)
    out: List[Array] = []
    for i, sp in enumerate(specs):
        if sp.init == "zeros":
            out.append(jnp.zeros(sp.shape, jnp.float32))
        elif sp.init == "ones":
            out.append(jnp.ones(sp.shape, jnp.float32))
        else:
            sub = jax.random.fold_in(key, i)
            fan_in = sp.shape[0] if len(sp.shape) > 1 else sp.shape[-1]
            std = 0.02 if "emb" in sp.name else (1.0 / jnp.sqrt(fan_in))
            out.append(std * jax.random.normal(sub, sp.shape, jnp.float32))
    return out


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _ops(use_pallas: bool):
    if use_pallas:
        return kernels.attention, kernels.layernorm, kernels.softmax_xent, kref.gelu_ref
    return (
        lambda q, k, v, causal=True: kref.attention_ref(q, k, v, causal=causal),
        kref.layernorm_ref,
        kref.softmax_xent_ref,
        kref.gelu_ref,
    )


def forward(
    cfg: ModelConfig,
    variant: str,
    params: Dict[str, Array],
    tokens: Array,      # [B, S] int32
    targets: Array,     # [B, S] int32 (already shifted by the data pipeline)
    weights: Array,     # [B, S] f32 loss mask
    use_pallas: bool = True,
) -> Tuple[Array, Array]:
    """Returns (mean masked loss, masked #correct) — one artifact serves
    training (loss, grads), evaluation (loss + accuracy) and MeZO (loss)."""
    attention, layernorm, softmax_xent, gelu = _ops(use_pallas)
    b, s = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head

    x = params["tok_emb"][tokens] + params["pos_emb"][:s][None, :, :]
    n_pre = 0
    if variant == "prefix":
        n_pre = cfg.n_prefix
        pre = params["prefix.emb"] + params["pos_emb"][s : s + n_pre]
        x = jnp.concatenate([jnp.broadcast_to(pre[None], (b, n_pre, d)), x], axis=1)
    t = s + n_pre  # total sequence length seen by the blocks

    for i in range(cfg.n_layers):
        p = f"l{i}."
        hx = layernorm(x, params[p + "ln1.scale"], params[p + "ln1.bias"])
        wq, wv = params[p + "attn.wq"], params[p + "attn.wv"]
        if variant == "lora":
            sc = cfg.lora_alpha / cfg.lora_rank
            wq = wq + sc * (params[p + "lora.aq"] @ params[p + "lora.bq"])
            wv = wv + sc * (params[p + "lora.av"] @ params[p + "lora.bv"])
        q = hx @ wq + params[p + "attn.bq"]
        k = hx @ params[p + "attn.wk"] + params[p + "attn.bk"]
        v = hx @ wv + params[p + "attn.bv"]
        if variant == "ia3":
            k = k * params[p + "ia3.lk"]
            v = v * params[p + "ia3.lv"]
        # [B, T, D] -> [B, H, T, Dh]
        q = q.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        k = k.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        v = v.reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        o = attention(q, k, v, True)
        o = o.transpose(0, 2, 1, 3).reshape(b, t, d)
        x = x + o @ params[p + "attn.wo"] + params[p + "attn.bo"]
        hx = layernorm(x, params[p + "ln2.scale"], params[p + "ln2.bias"])
        mid = gelu(hx @ params[p + "ffn.w1"] + params[p + "ffn.b1"])
        if variant == "ia3":
            mid = mid * params[p + "ia3.lff"]
        x = x + mid @ params[p + "ffn.w2"] + params[p + "ffn.b2"]

    hx = layernorm(x, params["ln_f.scale"], params["ln_f.bias"])
    logits = hx @ params["head.w"] + params["head.b"]  # [B, T, V]
    if n_pre:
        logits = logits[:, n_pre:, :]

    flat_logits = logits.reshape(b * s, cfg.vocab)
    flat_tgt = targets.reshape(b * s).astype(jnp.int32)
    flat_w = weights.reshape(b * s)
    nll = softmax_xent(flat_logits, flat_tgt)
    denom = jnp.maximum(jnp.sum(flat_w), 1e-6)
    loss = jnp.sum(nll * flat_w) / denom
    preds = jnp.argmax(flat_logits, axis=-1).astype(jnp.int32)
    ncorrect = jnp.sum((preds == flat_tgt).astype(jnp.float32) * flat_w)
    return loss, ncorrect


# --------------------------------------------------------------------------
# Lowerable entry points (flat positional params — AOT input order)
# --------------------------------------------------------------------------

def make_fns(
    cfg: ModelConfig, variant: str, use_pallas: bool
) -> Tuple[List[ParamSpec], Callable, Callable]:
    """Returns (all_specs, fwd_fn, grad_fn_factory).

    ``fwd_fn(*params, tokens, targets, weights) -> (loss, ncorrect)``.
    ``grad_fn_factory(idxs)`` builds a function additionally returning the
    gradients w.r.t. ``params[i] for i in idxs`` (a layer unit or adapter
    set) — grads for anything else are never formed, which is exactly the
    HiFT memory story at the XLA level.
    """
    specs = param_specs(cfg) + adapter_specs(cfg, variant)
    names = [sp.name for sp in specs]

    def as_dict(flat: Sequence[Array]) -> Dict[str, Array]:
        return dict(zip(names, flat))

    def fwd_fn(*args):
        *flat, tokens, targets, weights = args
        return forward(cfg, variant, as_dict(flat), tokens, targets, weights, use_pallas)

    def grad_fn_factory(idxs: Sequence[int]) -> Callable:
        idxs = tuple(idxs)

        def loss_of_subset(subset, rest, tokens, targets, weights):
            flat: List[Array] = []
            it_s, it_r = iter(subset), iter(rest)
            for i in range(len(specs)):
                flat.append(next(it_s) if i in idxs else next(it_r))
            loss, ncorrect = forward(
                cfg, variant, as_dict(flat), tokens, targets, weights, use_pallas
            )
            return loss, ncorrect

        def grad_fn(*args):
            *flat, tokens, targets, weights = args
            subset = [flat[i] for i in idxs]
            rest = [flat[i] for i in range(len(specs)) if i not in idxs]
            rest = [jax.lax.stop_gradient(r) for r in rest]
            (loss, ncorrect), grads = jax.value_and_grad(loss_of_subset, has_aux=True)(
                subset, rest, tokens, targets, weights
            )
            return (loss, ncorrect, *grads)

        return grad_fn

    return specs, fwd_fn, grad_fn_factory


def example_batch(cfg: ModelConfig):
    """Shape/dtype structs for lowering."""
    b, s = cfg.batch, cfg.seq_len
    return (
        jax.ShapeDtypeStruct((b, s), jnp.int32),
        jax.ShapeDtypeStruct((b, s), jnp.int32),
        jax.ShapeDtypeStruct((b, s), jnp.float32),
    )
