"""AOT compile path: lower the L2 model to HLO-text artifacts for Rust.

Runs ONCE at build time (``make artifacts``); Python is never on the
training path.  For a model preset this emits, under
``artifacts/<preset>/``:

  manifest.json      everything Rust needs: config, parameter specs
                     (name/shape/unit/offset), artifact inventory with
                     exact input orderings, VMEM kernel report.
  params.bin         initial base parameters, concatenated f32 LE.
  adapters_<v>.bin   initial adapter parameters per PEFT variant.
  fwd_<variant>.hlo.txt          (loss, ncorrect)
  grad_<variant>_u<i>.hlo.txt    (loss, ncorrect, grads of unit i)   [base]
  grad_<variant>_adapter.hlo.txt (loss, ncorrect, grads of adapters) [peft]
  grad_base_bitfit.hlo.txt       (loss, ncorrect, grads of bias/LN params)
  grad_base_full.hlo.txt         (…, grads of everything)            [FPFT]

Interchange is HLO **text**, never ``.serialize()``: jax>=0.5 emits protos
with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Sequence

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.flash_attention import vmem_estimate

VARIANTS = ("base", "lora", "ia3", "prefix")


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps a single tuple literal)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, specs: Sequence[M.ParamSpec], cfg: M.ModelConfig) -> str:
    param_structs = [jax.ShapeDtypeStruct(sp.shape, np.float32) for sp in specs]
    batch = M.example_batch(cfg)
    lowered = jax.jit(fn).lower(*param_structs, *batch)
    return to_hlo_text(lowered)


def write_bin(path: str, arrays: Sequence[jax.Array]) -> List[int]:
    """Concatenate f32 arrays little-endian; return per-tensor byte offsets."""
    offsets, off = [], 0
    with open(path, "wb") as f:
        for a in arrays:
            buf = np.asarray(a, dtype="<f4").tobytes()
            offsets.append(off)
            f.write(buf)
            off += len(buf)
    return offsets


def spec_json(sp: M.ParamSpec, offset: int) -> dict:
    return {
        "name": sp.name,
        "shape": list(sp.shape),
        "unit": sp.unit,
        "bitfit": sp.bitfit,
        "offset": offset,
        "size": sp.size,
    }


def build_preset(preset: str, out_root: str, kernels: str, variants: Sequence[str],
                 seed: int, verbose: bool = True) -> dict:
    cfg = M.PRESETS[preset]
    use_pallas = kernels == "pallas"
    out_dir = os.path.join(out_root, preset)
    os.makedirs(out_dir, exist_ok=True)

    base_specs = M.param_specs(cfg)
    base_params = M.init_params(cfg, base_specs, seed=seed)
    base_offsets = write_bin(os.path.join(out_dir, "params.bin"), base_params)

    artifacts: List[dict] = []

    def emit(name: str, text: str, inputs: List[str], outputs: List[str]):
        path = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        artifacts.append({"name": name, "path": path, "inputs": inputs, "outputs": outputs})
        if verbose:
            print(f"  wrote {path} ({len(text)} chars)", flush=True)

    manifest: Dict = {
        "schema": 1,
        "preset": preset,
        "kernels": kernels,
        "seed": seed,
        "config": cfg.to_json_dict(),
        "n_units": cfg.n_units,
        "variants": {},
        "artifacts": artifacts,
        "vmem_report": vmem_estimate(cfg.batch, cfg.n_heads,
                                     cfg.seq_len + cfg.n_prefix, cfg.d_head),
    }

    batch_inputs = ["tokens", "targets", "weights"]
    for variant in variants:
        t0 = time.time()
        specs, fwd_fn, grad_factory = M.make_fns(cfg, variant, use_pallas)
        names = [sp.name for sp in specs]
        adapters = specs[len(base_specs):]
        if variant != "base":
            ad_params = M.init_params(cfg, adapters, seed=seed + 1)
            ad_offsets = write_bin(os.path.join(out_dir, f"adapters_{variant}.bin"), ad_params)
        else:
            ad_offsets = []

        manifest["variants"][variant] = {
            "params": [spec_json(sp, base_offsets[i]) for i, sp in enumerate(base_specs)]
            + [spec_json(sp, ad_offsets[i]) for i, sp in enumerate(adapters)],
            "n_base_params": len(base_specs),
        }

        emit(f"fwd_{variant}", lower_fn(fwd_fn, specs, cfg),
             names + batch_inputs, ["loss", "ncorrect"])

        if variant == "base":
            # One grad artifact per layer unit (HiFT composes these), plus
            # the FPFT full gradient and the BitFit subset.
            for u in range(cfg.n_units):
                idxs = [i for i, sp in enumerate(specs) if sp.unit == u]
                g = grad_factory(idxs)
                emit(f"grad_base_u{u}", lower_fn(g, specs, cfg), names + batch_inputs,
                     ["loss", "ncorrect"] + [names[i] for i in idxs])
            full = list(range(len(specs)))
            emit("grad_base_full", lower_fn(grad_factory(full), specs, cfg),
                 names + batch_inputs, ["loss", "ncorrect"] + names)
            bitf = [i for i, sp in enumerate(specs) if sp.bitfit]
            emit("grad_base_bitfit", lower_fn(grad_factory(bitf), specs, cfg),
                 names + batch_inputs, ["loss", "ncorrect"] + [names[i] for i in bitf])
        else:
            idxs = [i for i, sp in enumerate(specs) if sp.unit == -1]
            emit(f"grad_{variant}_adapter", lower_fn(grad_factory(idxs), specs, cfg),
                 names + batch_inputs, ["loss", "ncorrect"] + [names[i] for i in idxs])
        if verbose:
            print(f"  variant {variant}: {time.time()-t0:.1f}s", flush=True)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="tiny,small", help="comma-separated preset names")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--kernels", default="pallas", choices=("pallas", "ref"))
    ap.add_argument("--variants", default="base,lora,ia3,prefix")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--report", action="store_true", help="print VMEM kernel report only")
    args = ap.parse_args(argv)

    presets = [p.strip() for p in args.preset.split(",") if p.strip()]
    variants = [v.strip() for v in args.variants.split(",") if v.strip()]
    for p in presets:
        if p not in M.PRESETS:
            print(f"unknown preset {p!r}; have {sorted(M.PRESETS)}", file=sys.stderr)
            return 2
    if args.report:
        for p in presets:
            cfg = M.PRESETS[p]
            print(p, vmem_estimate(cfg.batch, cfg.n_heads,
                                   cfg.seq_len + cfg.n_prefix, cfg.d_head))
        return 0
    for p in presets:
        print(f"[aot] building preset {p} (kernels={args.kernels})", flush=True)
        t0 = time.time()
        build_preset(p, args.out_dir, args.kernels, variants, args.seed)
        print(f"[aot] preset {p} done in {time.time()-t0:.1f}s", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
